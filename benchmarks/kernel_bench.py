"""Pallas kernel micro-bench (interpret mode on CPU).

Two sections, both landing in ``BENCH_kernels.json`` at the repo root as
the measured-perf trajectory:

* ``shapes`` — Mode 1 vs Mode 2 GEMM: the zero-skipping kernel contracts
  x deep instead of y*x deep and holds 1/y of the RHS; fused vs unfused
  epilogue.

* ``implicit_conv`` — implicit-GEMM conv vs the materialized im2col->GEMM
  oracle over every conv layer of the serving-zoo paper-CNN stand-ins,
  with the per-shape peak activation-stream estimate (elements at a
  common width): im2col holds a (B, P, K*K*D) DIV matrix, the implicit
  path only the (B, Hp, Wp, D) padded activation — a K^2-ish footprint
  ratio for K>1 (EXPERIMENTS.md §Perf "Dispatch & memory").

* ``quantized_domain`` — the int8-vs-float sweep over EVERY serving-zoo
  layer shape (conv AND FC): the fused-quantize int8 path
  (engine.forward_layer — DAC absmax/quantize in the kernel prologues,
  int8 operand streams, double-buffered K-pipelining) against the
  quantize-then-float oracle (engine.forward_layer_f32 — separate XLA
  quantize passes, lattice values streamed as f32), re-checked bitwise at
  every timed shape, with the modeled per-layer HBM bytes each path moves
  (EXPERIMENTS.md §Quantized-domain execution).

Wall-times in interpret mode are NOT TPU times — the derived structural
metrics (MXU passes, HBM bytes) are machine-independent; wall times are
tracked as a trajectory (same machine, same method).  Timings take warmup
iterations first (trace+compile excluded) and block_until_ready around
every measured call.

``python -m benchmarks.kernel_bench --smoke`` runs the CI smoke: tiny
shapes, asserts the implicit-GEMM path is actually selected for every
serving-zoo conv layer and bitwise-matches the im2col oracle (and the
whole-model jitted pipeline matches the eager loop), without touching the
JSON artifact.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.cnn.layers import ConvKind
from repro.core import vdp
from repro.engine import executor as ex
from repro.kernels import ops, ref
from repro.kernels.vdpe_conv import conv_window_bounds
from repro.serve import models as zoo

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_kernels.json"

# best-of-9 with two warmups: single-round best-of-5 left the sub-ms conv
# timings (and therefore the speedup ratios scripts/check_bench.py gates
# on) with >2x cross-run variance on small CI hosts
WARMUP = 2
ITERS = 9


def _check(ok: bool, msg: str) -> None:
    """Benchmark/smoke invariant — a real raise, not a bare ``assert``
    (the CI gate must fail under ``python -O`` too)."""
    if not ok:
        raise RuntimeError(msg)


def _time(fn, *args, iters: int = ITERS, **kwargs) -> float:
    """Best-of-iters wall seconds, post-warmup, synchronized."""
    for _ in range(WARMUP):
        jax.block_until_ready(fn(*args, **kwargs))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Mode-1 vs Mode-2 GEMM section
# ---------------------------------------------------------------------------

def gemm_section() -> Dict:
    rng = np.random.default_rng(0)
    # large enough that contraction work (not interpret-loop overhead)
    # dominates: the zero-skipping win is the x vs y*x contraction depth
    p, f = 1024, 512
    y = ops.N_TPU // ops.X_TPU
    results: dict = {"p": p, "f": f, "x": ops.X_TPU, "y": y, "shapes": {}}
    for s in (9, 25, 32):
        divs = jnp.asarray(rng.integers(-7, 8, (p, s)), jnp.int8)
        dkvs = jnp.asarray(rng.integers(-7, 8, (f, s)), jnp.int8)
        # structural model: MXU passes and HBM bytes per output tile
        passes_m1 = -(-s // ops.N_TPU) * f
        passes_m2 = -(-s // ops.X_TPU) * -(-f // y)
        bytes_m1 = p * ops.N_TPU            # padded dense lhs reads
        bytes_m2 = p * ops.X_TPU            # packed lhs read once

        # the pre-PR block-diagonal kernel (now the oracle in ref.py)
        pp = -(-p // 128) * 128
        lhs_pad = jnp.pad(divs, ((0, pp - p), (0, ops.X_TPU - s)))
        rhs_bd = ops.pack_mode2_weights(dkvs, ops.X_TPU, y)  # f=128 aligned
        t_bd = _time(ref.vdpe_pack_gemm_blockdiag, lhs_pad, rhs_bd, y,
                     interpret=True)
        t_zs = _time(ops.mode2_gemm, divs, dkvs, ops.X_TPU, y,
                     interpret=True)
        t_m1 = _time(ops.mode1_gemm, divs, dkvs, interpret=True)
        # fused epilogue vs unfused + separate dequant/bias/relu
        scale = jnp.float32(0.01)
        bias = jnp.asarray(rng.normal(size=(f,)), jnp.float32)
        t_fused = _time(ops.mode2_gemm, divs, dkvs, ops.X_TPU, y,
                        interpret=True, scale=scale, bias=bias, act="relu")

        def unfused(divs, dkvs, scale, bias):
            acc = ops.mode2_gemm(divs, dkvs, ops.X_TPU, y, interpret=True)
            return ref.epilogue_ref(acc, scale, bias[None, :], "relu")

        t_unfused = _time(unfused, divs, dkvs, scale, bias)

        out_zs = ops.mode2_gemm(divs, dkvs, ops.X_TPU, y, interpret=True)
        out_bd = ref.vdpe_pack_gemm_blockdiag(lhs_pad, rhs_bd, y,
                                              interpret=True)[:p, :f]
        _check(np.array_equal(np.asarray(out_zs), np.asarray(out_bd)),
               f"zero-skipping kernel diverged from block-diagonal "
               f"oracle at S={s}")

        row = {
            "mxu_pass_ratio": passes_m1 / passes_m2,
            "lhs_hbm_ratio": bytes_m1 / bytes_m2,
            "contraction_depth_zs": ops.X_TPU,
            "contraction_depth_blockdiag": y * ops.X_TPU,
            "mode2_zs_s": t_zs,
            "mode2_blockdiag_s": t_bd,
            "mode1_s": t_m1,
            "mode2_fused_epilogue_s": t_fused,
            "mode2_unfused_epilogue_s": t_unfused,
        }
        results["shapes"][f"S={s}"] = row
        print(f"kernel,S={s},mxu_pass_ratio={row['mxu_pass_ratio']:.2f},"
              f"lhs_hbm_ratio={row['lhs_hbm_ratio']:.2f},"
              f"zs_s={t_zs:.4f},blockdiag_s={t_bd:.4f},mode1_s={t_m1:.4f},"
              f"fused_s={t_fused:.4f},unfused_s={t_unfused:.4f},"
              f"zs_speedup_vs_blockdiag={t_bd / t_zs:.2f}x")
    return results


# ---------------------------------------------------------------------------
# Implicit-GEMM conv vs im2col+GEMM section
# ---------------------------------------------------------------------------

def layer_cases(include_fc: bool = False,
                ) -> List[Tuple[str, object, Tuple[int, int, int]]]:
    """(model, LayerPlan, input HWC) for every serving-zoo layer.

    FC layers (the serving zoo puts them last) receive their input as the
    preceding feature map's (H, W, D) — the executor flattens it.
    """
    _build_plans()
    cases = []
    for name in zoo.SERVING_MODELS:
        plan = _PLAN_BY_MODEL[name]
        h, w, d = zoo.serving_input_shape(name)
        for lp in plan.layers:
            if lp.kind is ConvKind.FC:
                if include_fc:
                    cases.append((name, lp, (h, w, d)))
                break                       # spatial structure ends here
            cases.append((name, lp, (h, w, d)))
            h, w = vdp.out_hw(h, w, lp.k, lp.stride, lp.padding)
            d = lp.f
    return cases


def conv_cases() -> List[Tuple[str, object, Tuple[int, int, int]]]:
    """(model, LayerPlan, input HWC) for every serving-zoo conv layer."""
    return layer_cases(include_fc=False)


def _conv_footprints(lp, in_shape) -> Tuple[Tuple[int, int],
                                            Tuple[int, int]]:
    """((ho, wo), (hp, wp)): one conv layer's output and padded-input dims.

    The single home of the SAME/VALID padded-footprint arithmetic — both
    HBM models below (implicit-vs-im2col and int8-vs-float) price the
    same (B, Hp, Wp, D) activation the kernels actually fetch.
    """
    h, w, d = in_shape
    ho, wo = vdp.out_hw(h, w, lp.k, lp.stride, lp.padding)
    if lp.padding == "SAME":
        hp, wp = conv_window_bounds(lp.k, lp.stride, ho, wo)
        hp, wp = max(hp, h), max(wp, w)
    else:
        hp, wp = h, w
    return (ho, wo), (hp, wp)


def _stream_bytes(lp, in_shape, batch: int) -> Tuple[int, int]:
    """(im2col, implicit) peak activation-stream size for one layer.

    Counted in *elements at a common width* (dtype-neutral — since PR 5
    both paths peak on an f32-held activation: the im2col path builds the
    f32 (B, P, K*K*D) DIV matrix before quantizing, the implicit q8 path
    fetches the raw f32 (B, Hp, Wp, D) map), so the ratio is the K²-ish
    footprint blow-up of materializing the DIV matrix at all.  The
    per-HBM-pass byte model of the int8-vs-float comparison is
    ``_q8_hbm_bytes`` below.
    """
    d = in_shape[2]
    (ho, wo), (hp, wp) = _conv_footprints(lp, in_shape)
    im2col = batch * ho * wo * lp.k * lp.k * d
    implicit = batch * hp * wp * d
    return im2col, implicit


def conv_section(batch: int = 4, iters: int = ITERS,
                 seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    results: Dict = {"batch": batch, "layers": {}}
    peak_im2col: Dict[str, int] = {}
    peak_implicit: Dict[str, int] = {}
    for model, lp, in_shape in conv_cases():
        x = jnp.asarray(rng.normal(size=(batch, *in_shape)), jnp.float32)
        plan = _PLAN_BY_MODEL[model]
        t_imp = _time(ex.forward_layer, plan, lp, x,
                      iters=iters, interpret=True)
        t_i2c = _time(ex.forward_layer_im2col, plan, lp, x,
                      iters=iters, interpret=True)
        # a benchmark that silently drifts from the oracle is worse than a
        # slow one — every timed shape re-checks bitwise equality
        a = ex.forward_layer(plan, lp, x, interpret=True)
        b = ex.forward_layer_im2col(plan, lp, x, interpret=True)
        _check(np.array_equal(np.asarray(a), np.asarray(b)),
               f"implicit conv diverged from im2col oracle at "
               f"{model}/{lp.name}")
        by_i2c, by_imp = _stream_bytes(lp, in_shape, batch)
        peak_im2col[model] = max(peak_im2col.get(model, 0), by_i2c)
        peak_implicit[model] = max(peak_implicit.get(model, 0), by_imp)
        key = f"{model}/{lp.name}"
        results["layers"][key] = {
            "kind": lp.kind.value, "k": lp.k, "stride": lp.stride,
            "route": engine.layer_route(lp),
            "implicit_s": t_imp, "im2col_s": t_i2c,
            "implicit_speedup": t_i2c / t_imp,
            "im2col_stream_bytes": by_i2c,
            "implicit_stream_bytes": by_imp,
            "stream_bytes_ratio": by_i2c / by_imp,
        }
        print(f"implicit_conv,{key},{lp.kind.value},k={lp.k},"
              f"implicit_s={t_imp:.4f},im2col_s={t_i2c:.4f},"
              f"speedup={t_i2c / t_imp:.2f}x,"
              f"stream_ratio={by_i2c / by_imp:.2f}x")
    results["peak_stream_bytes"] = {
        m: {"im2col": peak_im2col[m], "implicit": peak_implicit[m],
            "ratio": peak_im2col[m] / peak_implicit[m]}
        for m in peak_im2col}
    for m, row in results["peak_stream_bytes"].items():
        print(f"implicit_conv,peak_stream,{m},im2col={row['im2col']},"
              f"implicit={row['implicit']},ratio={row['ratio']:.2f}x")
    return results


# ---------------------------------------------------------------------------
# Quantized-domain execution: int8 path vs quantize-then-float oracle
# ---------------------------------------------------------------------------

def _q8_hbm_bytes(lp, in_shape, batch: int) -> Tuple[int, int]:
    """(int8-path, float-path) modeled HBM bytes one layer call moves.

    Counts every activation/weight pass each path actually performs:

    * conv int8 (fused prologue): the raw f32 activation is fetched ONCE
      by the kernel (absmax + quantize happen off the VMEM tile) and the
      resident weights stream as int8.
    * conv float (quantize-then-float): XLA absmax read + quantize
      read/write of the f32 lattice + kernel read (4 activation passes),
      weights cast int8->f32 (read+write) then kernel-read as f32.
    * FC int8: the row absmax is one XLA read, the quantize is fused
      (kernel reads the raw f32 rows) — 2 activation passes; int8 weights.
    * FC float: like conv float (4 activation passes, f32 weights).
    * DC runs the integer VPU path in both domains (int32 vs f32 lattice,
      4 bytes either way): equal traffic, ratio 1.
    """
    w_elems = int(np.prod(lp.rhs.shape))
    if lp.kind is ConvKind.FC:
        a_elems = batch * lp.s
        return (a_elems * (4 + 4) + w_elems * 1,
                a_elems * (4 + 4 + 4 + 4) + w_elems * (1 + 4 + 4))
    _, (hp, wp) = _conv_footprints(lp, in_shape)
    a_elems = batch * hp * wp * in_shape[2]
    if lp.mode == engine.MODE_DEPTHWISE:
        n = a_elems * (4 + 4 + 4 + 4) + w_elems * 4
        return n, n
    return (a_elems * 4 + w_elems * 1,
            a_elems * (4 + 4 + 4 + 4) + w_elems * (1 + 4 + 4))


def quantized_section(batch: int = 4, iters: int = ITERS,
                      seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    results: Dict = {"batch": batch, "layers": {}}
    tot8 = totf = 0
    for model, lp, in_shape in layer_cases(include_fc=True):
        x = jnp.asarray(rng.normal(size=(batch, *in_shape)), jnp.float32)
        plan = _PLAN_BY_MODEL[model]
        t_q8 = _time(ex.forward_layer, plan, lp, x,
                     iters=iters, interpret=True)
        t_f32 = _time(ex.forward_layer_f32, plan, lp, x,
                      iters=iters, interpret=True)
        a = ex.forward_layer(plan, lp, x, interpret=True)
        b = ex.forward_layer_f32(plan, lp, x, interpret=True)
        _check(np.array_equal(np.asarray(a), np.asarray(b)),
               f"int8 path diverged from quantize-then-float oracle at "
               f"{model}/{lp.name}")
        by8, byf = _q8_hbm_bytes(lp, in_shape, batch)
        tot8 += by8
        totf += byf
        key = f"{model}/{lp.name}"
        results["layers"][key] = {
            "kind": lp.kind.value, "k": lp.k, "stride": lp.stride,
            "route": engine.layer_route(lp),
            "int8_s": t_q8, "float_s": t_f32,
            "q8_speedup": t_f32 / t_q8,
            "hbm_bytes_int8": by8, "hbm_bytes_float": byf,
            "hbm_ratio": byf / by8,
        }
        print(f"quantized_domain,{key},{lp.kind.value},"
              f"int8_s={t_q8:.4f},float_s={t_f32:.4f},"
              f"q8_speedup={t_f32 / t_q8:.2f}x,hbm_ratio={byf / by8:.2f}x")
    speedups = [r["q8_speedup"] for r in results["layers"].values()]
    results["geomean_q8_speedup"] = float(
        np.exp(np.mean(np.log(speedups))))
    results["total_hbm_bytes"] = {
        "int8": tot8, "float": totf, "ratio": totf / tot8}
    print(f"quantized_domain,geomean_q8_speedup="
          f"{results['geomean_q8_speedup']:.2f}x,"
          f"total_hbm_ratio={totf / tot8:.2f}x")
    return results


_PLAN_BY_MODEL: Dict[str, engine.ModelPlan] = {}


def _build_plans() -> None:
    for name in zoo.SERVING_MODELS:
        if name not in _PLAN_BY_MODEL:
            _PLAN_BY_MODEL[name] = engine.compile_model(
                f"kbench_{name}", zoo.serving_defs(name, 0))


def run() -> None:
    results = gemm_section()
    results["implicit_conv"] = conv_section()
    results["quantized_domain"] = quantized_section()
    OUT_PATH.write_text(json.dumps(results, indent=2) + "\n")
    print(f"kernel_bench,json,{OUT_PATH}")


def smoke() -> None:
    """CI smoke: route + bitwise assertions on tiny shapes, no JSON.

    Fails loudly if a regression knocks conv layers off the implicit path
    or breaks its bitwise agreement with the im2col oracle — instead of
    only skewing the BENCH_*.json artifacts.
    """
    rng = np.random.default_rng(0)
    n_conv = 0
    for model, lp, in_shape in conv_cases():
        route = engine.layer_route(lp)
        _check(route in (ex.ROUTE_CONV_M1, ex.ROUTE_CONV_ZS,
                         ex.ROUTE_DEPTHWISE),
               f"{model}/{lp.name} fell off the implicit path: {route}")
        if route != ex.ROUTE_DEPTHWISE:
            n_conv += 1
        x = jnp.asarray(rng.normal(size=(2, *in_shape)), jnp.float32)
        plan = _PLAN_BY_MODEL[model]
        a = ex.forward_layer(plan, lp, x, interpret=True)
        b = ex.forward_layer_im2col(plan, lp, x, interpret=True)
        _check(np.array_equal(np.asarray(a), np.asarray(b)),
               f"implicit conv diverged from im2col oracle at "
               f"{model}/{lp.name}")
        print(f"smoke,layer,{model}/{lp.name},{route},bitwise=ok")
    _check(n_conv > 0, "no conv layer routed to the implicit kernels")
    # quantized-domain path == quantize-then-float oracle, every layer
    # shape including FC
    for model, lp, in_shape in layer_cases(include_fc=True):
        x = jnp.asarray(rng.normal(size=(2, *in_shape)), jnp.float32)
        plan = _PLAN_BY_MODEL[model]
        a = ex.forward_layer(plan, lp, x, interpret=True)
        b = ex.forward_layer_f32(plan, lp, x, interpret=True)
        _check(np.array_equal(np.asarray(a), np.asarray(b)),
               f"int8 path diverged from quantize-then-float oracle at "
               f"{model}/{lp.name}")
        print(f"smoke,quantized,{model}/{lp.name},bitwise=ok")
    # whole-model jitted pipeline == eager loop == float oracle
    engine.pipeline_cache_clear()
    for model, plan in _PLAN_BY_MODEL.items():
        shape = zoo.serving_input_shape(model)
        x = jnp.asarray(rng.normal(size=(3, *shape)), jnp.float32)
        got = engine.forward_jit(plan, x, interpret=True)
        want = engine.forward(plan, x, interpret=True)
        _check(np.array_equal(np.asarray(got), np.asarray(want)),
               f"whole-model jit diverged from the eager loop for {model}")
        oracle = engine.forward_f32(plan, x, interpret=True)
        _check(np.array_equal(np.asarray(got), np.asarray(oracle)),
               f"whole-model jit diverged from the float oracle for {model}")
        print(f"smoke,pipeline,{model},bitwise=ok")
    _check(engine.pipeline_cache_info()["compiles"] == len(_PLAN_BY_MODEL),
           "pipeline compiled more than once per (plan, bucket)")
    print("smoke,PASS")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI assertions (no JSON artifact)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        run()


if __name__ == "__main__":
    main()
