"""Pallas kernel micro-bench (interpret mode on CPU).

Two sections, both landing in ``BENCH_kernels.json`` at the repo root as
the measured-perf trajectory:

* ``shapes`` — Mode 1 vs Mode 2 GEMM: the zero-skipping kernel contracts
  x deep instead of y*x deep and holds 1/y of the RHS; fused vs unfused
  epilogue.

* ``implicit_conv`` — implicit-GEMM conv vs the materialized im2col->GEMM
  oracle over every conv layer of the serving-zoo paper-CNN stand-ins,
  with the per-shape peak activation-stream HBM estimate: im2col holds a
  (B, P, K*K*D) DIV matrix, the implicit path only the (B, Hp, Wp, D)
  padded activation — a K^2-ish footprint ratio for K>1 (EXPERIMENTS.md
  §Perf "Dispatch & memory").

Wall-times in interpret mode are NOT TPU times — the derived structural
metrics (MXU passes, HBM bytes) are machine-independent; wall times are
tracked as a trajectory (same machine, same method).  Timings take warmup
iterations first (trace+compile excluded) and block_until_ready around
every measured call.

``python -m benchmarks.kernel_bench --smoke`` runs the CI smoke: tiny
shapes, asserts the implicit-GEMM path is actually selected for every
serving-zoo conv layer and bitwise-matches the im2col oracle (and the
whole-model jitted pipeline matches the eager loop), without touching the
JSON artifact.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.cnn.layers import ConvKind
from repro.core import vdp
from repro.engine import executor as ex
from repro.kernels import ops, ref
from repro.kernels.vdpe_conv import conv_window_bounds
from repro.serve import models as zoo

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_kernels.json"

# best-of-9 with two warmups: single-round best-of-5 left the sub-ms conv
# timings (and therefore the speedup ratios scripts/check_bench.py gates
# on) with >2x cross-run variance on small CI hosts
WARMUP = 2
ITERS = 9


def _check(ok: bool, msg: str) -> None:
    """Benchmark/smoke invariant — a real raise, not a bare ``assert``
    (the CI gate must fail under ``python -O`` too)."""
    if not ok:
        raise RuntimeError(msg)


def _time(fn, *args, iters: int = ITERS, **kwargs) -> float:
    """Best-of-iters wall seconds, post-warmup, synchronized."""
    for _ in range(WARMUP):
        jax.block_until_ready(fn(*args, **kwargs))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Mode-1 vs Mode-2 GEMM section
# ---------------------------------------------------------------------------

def gemm_section() -> Dict:
    rng = np.random.default_rng(0)
    # large enough that contraction work (not interpret-loop overhead)
    # dominates: the zero-skipping win is the x vs y*x contraction depth
    p, f = 1024, 512
    y = ops.N_TPU // ops.X_TPU
    results: dict = {"p": p, "f": f, "x": ops.X_TPU, "y": y, "shapes": {}}
    for s in (9, 25, 32):
        divs = jnp.asarray(rng.integers(-7, 8, (p, s)), jnp.int8)
        dkvs = jnp.asarray(rng.integers(-7, 8, (f, s)), jnp.int8)
        # structural model: MXU passes and HBM bytes per output tile
        passes_m1 = -(-s // ops.N_TPU) * f
        passes_m2 = -(-s // ops.X_TPU) * -(-f // y)
        bytes_m1 = p * ops.N_TPU            # padded dense lhs reads
        bytes_m2 = p * ops.X_TPU            # packed lhs read once

        # the pre-PR block-diagonal kernel (now the oracle in ref.py)
        pp = -(-p // 128) * 128
        lhs_pad = jnp.pad(divs, ((0, pp - p), (0, ops.X_TPU - s)))
        rhs_bd = ops.pack_mode2_weights(dkvs, ops.X_TPU, y)  # f=128 aligned
        t_bd = _time(ref.vdpe_pack_gemm_blockdiag, lhs_pad, rhs_bd, y,
                     interpret=True)
        t_zs = _time(ops.mode2_gemm, divs, dkvs, ops.X_TPU, y,
                     interpret=True)
        t_m1 = _time(ops.mode1_gemm, divs, dkvs, interpret=True)
        # fused epilogue vs unfused + separate dequant/bias/relu
        scale = jnp.float32(0.01)
        bias = jnp.asarray(rng.normal(size=(f,)), jnp.float32)
        t_fused = _time(ops.mode2_gemm, divs, dkvs, ops.X_TPU, y,
                        interpret=True, scale=scale, bias=bias, act="relu")

        def unfused(divs, dkvs, scale, bias):
            acc = ops.mode2_gemm(divs, dkvs, ops.X_TPU, y, interpret=True)
            return ref.epilogue_ref(acc, scale, bias[None, :], "relu")

        t_unfused = _time(unfused, divs, dkvs, scale, bias)

        out_zs = ops.mode2_gemm(divs, dkvs, ops.X_TPU, y, interpret=True)
        out_bd = ref.vdpe_pack_gemm_blockdiag(lhs_pad, rhs_bd, y,
                                              interpret=True)[:p, :f]
        _check(np.array_equal(np.asarray(out_zs), np.asarray(out_bd)),
               f"zero-skipping kernel diverged from block-diagonal "
               f"oracle at S={s}")

        row = {
            "mxu_pass_ratio": passes_m1 / passes_m2,
            "lhs_hbm_ratio": bytes_m1 / bytes_m2,
            "contraction_depth_zs": ops.X_TPU,
            "contraction_depth_blockdiag": y * ops.X_TPU,
            "mode2_zs_s": t_zs,
            "mode2_blockdiag_s": t_bd,
            "mode1_s": t_m1,
            "mode2_fused_epilogue_s": t_fused,
            "mode2_unfused_epilogue_s": t_unfused,
        }
        results["shapes"][f"S={s}"] = row
        print(f"kernel,S={s},mxu_pass_ratio={row['mxu_pass_ratio']:.2f},"
              f"lhs_hbm_ratio={row['lhs_hbm_ratio']:.2f},"
              f"zs_s={t_zs:.4f},blockdiag_s={t_bd:.4f},mode1_s={t_m1:.4f},"
              f"fused_s={t_fused:.4f},unfused_s={t_unfused:.4f},"
              f"zs_speedup_vs_blockdiag={t_bd / t_zs:.2f}x")
    return results


# ---------------------------------------------------------------------------
# Implicit-GEMM conv vs im2col+GEMM section
# ---------------------------------------------------------------------------

def conv_cases() -> List[Tuple[str, object, Tuple[int, int, int]]]:
    """(model, LayerPlan, input HWC) for every serving-zoo conv layer."""
    _build_plans()
    cases = []
    for name in zoo.SERVING_MODELS:
        plan = _PLAN_BY_MODEL[name]
        h, w, d = zoo.serving_input_shape(name)
        for lp in plan.layers:
            if lp.kind is ConvKind.FC:
                break                       # spatial structure ends here
            cases.append((name, lp, (h, w, d)))
            h, w = vdp.out_hw(h, w, lp.k, lp.stride, lp.padding)
            d = lp.f
    return cases


def _stream_bytes(lp, in_shape, batch: int) -> Tuple[int, int]:
    """(im2col, implicit) peak activation-stream bytes for one layer.

    im2col materializes the int8 (B, P, K*K*D) DIV matrix; the implicit
    path streams the int8 padded activation (B, Hp, Wp, D) straight into
    the kernel.
    """
    h, w, d = in_shape
    ho, wo = vdp.out_hw(h, w, lp.k, lp.stride, lp.padding)
    if lp.padding == "SAME":
        hp, wp = conv_window_bounds(lp.k, lp.stride, ho, wo)
        hp, wp = max(hp, h), max(wp, w)
    else:
        hp, wp = h, w
    im2col = batch * ho * wo * lp.k * lp.k * d
    implicit = batch * hp * wp * d
    return im2col, implicit


def conv_section(batch: int = 4, iters: int = ITERS,
                 seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    results: Dict = {"batch": batch, "layers": {}}
    peak_im2col: Dict[str, int] = {}
    peak_implicit: Dict[str, int] = {}
    for model, lp, in_shape in conv_cases():
        x = jnp.asarray(rng.normal(size=(batch, *in_shape)), jnp.float32)
        plan = _PLAN_BY_MODEL[model]
        t_imp = _time(ex.forward_layer, plan, lp, x,
                      iters=iters, interpret=True)
        t_i2c = _time(ex.forward_layer_im2col, plan, lp, x,
                      iters=iters, interpret=True)
        # a benchmark that silently drifts from the oracle is worse than a
        # slow one — every timed shape re-checks bitwise equality
        a = ex.forward_layer(plan, lp, x, interpret=True)
        b = ex.forward_layer_im2col(plan, lp, x, interpret=True)
        _check(np.array_equal(np.asarray(a), np.asarray(b)),
               f"implicit conv diverged from im2col oracle at "
               f"{model}/{lp.name}")
        by_i2c, by_imp = _stream_bytes(lp, in_shape, batch)
        peak_im2col[model] = max(peak_im2col.get(model, 0), by_i2c)
        peak_implicit[model] = max(peak_implicit.get(model, 0), by_imp)
        key = f"{model}/{lp.name}"
        results["layers"][key] = {
            "kind": lp.kind.value, "k": lp.k, "stride": lp.stride,
            "route": engine.layer_route(lp),
            "implicit_s": t_imp, "im2col_s": t_i2c,
            "implicit_speedup": t_i2c / t_imp,
            "im2col_stream_bytes": by_i2c,
            "implicit_stream_bytes": by_imp,
            "stream_bytes_ratio": by_i2c / by_imp,
        }
        print(f"implicit_conv,{key},{lp.kind.value},k={lp.k},"
              f"implicit_s={t_imp:.4f},im2col_s={t_i2c:.4f},"
              f"speedup={t_i2c / t_imp:.2f}x,"
              f"stream_ratio={by_i2c / by_imp:.2f}x")
    results["peak_stream_bytes"] = {
        m: {"im2col": peak_im2col[m], "implicit": peak_implicit[m],
            "ratio": peak_im2col[m] / peak_implicit[m]}
        for m in peak_im2col}
    for m, row in results["peak_stream_bytes"].items():
        print(f"implicit_conv,peak_stream,{m},im2col={row['im2col']},"
              f"implicit={row['implicit']},ratio={row['ratio']:.2f}x")
    return results


_PLAN_BY_MODEL: Dict[str, engine.ModelPlan] = {}


def _build_plans() -> None:
    for name in zoo.SERVING_MODELS:
        if name not in _PLAN_BY_MODEL:
            _PLAN_BY_MODEL[name] = engine.compile_model(
                f"kbench_{name}", zoo.serving_defs(name, 0))


def run() -> None:
    results = gemm_section()
    results["implicit_conv"] = conv_section()
    OUT_PATH.write_text(json.dumps(results, indent=2) + "\n")
    print(f"kernel_bench,json,{OUT_PATH}")


def smoke() -> None:
    """CI smoke: route + bitwise assertions on tiny shapes, no JSON.

    Fails loudly if a regression knocks conv layers off the implicit path
    or breaks its bitwise agreement with the im2col oracle — instead of
    only skewing the BENCH_*.json artifacts.
    """
    rng = np.random.default_rng(0)
    n_conv = 0
    for model, lp, in_shape in conv_cases():
        route = engine.layer_route(lp)
        _check(route in (ex.ROUTE_CONV_M1, ex.ROUTE_CONV_ZS,
                         ex.ROUTE_DEPTHWISE),
               f"{model}/{lp.name} fell off the implicit path: {route}")
        if route != ex.ROUTE_DEPTHWISE:
            n_conv += 1
        x = jnp.asarray(rng.normal(size=(2, *in_shape)), jnp.float32)
        plan = _PLAN_BY_MODEL[model]
        a = ex.forward_layer(plan, lp, x, interpret=True)
        b = ex.forward_layer_im2col(plan, lp, x, interpret=True)
        _check(np.array_equal(np.asarray(a), np.asarray(b)),
               f"implicit conv diverged from im2col oracle at "
               f"{model}/{lp.name}")
        print(f"smoke,layer,{model}/{lp.name},{route},bitwise=ok")
    _check(n_conv > 0, "no conv layer routed to the implicit kernels")
    # whole-model jitted pipeline == eager loop
    engine.pipeline_cache_clear()
    for model, plan in _PLAN_BY_MODEL.items():
        shape = zoo.serving_input_shape(model)
        x = jnp.asarray(rng.normal(size=(3, *shape)), jnp.float32)
        got = engine.forward_jit(plan, x, interpret=True)
        want = engine.forward(plan, x, interpret=True)
        _check(np.array_equal(np.asarray(got), np.asarray(want)),
               f"whole-model jit diverged from the eager loop for {model}")
        print(f"smoke,pipeline,{model},bitwise=ok")
    _check(engine.pipeline_cache_info()["compiles"] == len(_PLAN_BY_MODEL),
           "pipeline compiled more than once per (plan, bucket)")
    print("smoke,PASS")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI assertions (no JSON artifact)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        run()


if __name__ == "__main__":
    main()
