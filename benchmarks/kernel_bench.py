"""Pallas kernel micro-bench (interpret mode on CPU): Mode 1 vs Mode 2.

Wall-times in interpret mode are NOT TPU times — the derived metric that
matters is the MXU-pass and HBM-traffic model: the zero-skipping Mode-2
kernel contracts x deep instead of y*x deep and holds 1/y of the RHS
(EXPERIMENTS.md §Perf discusses the structural win and the measurement
method).  Timings take a warmup iteration first (trace+compile excluded)
and block_until_ready around every measured call; results land in
``BENCH_kernels.json`` at the repo root as the measured-perf trajectory.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_kernels.json"

WARMUP = 1
ITERS = 5


def _time(fn, *args, **kwargs) -> float:
    """Best-of-ITERS wall seconds, post-warmup, synchronized."""
    for _ in range(WARMUP):
        jax.block_until_ready(fn(*args, **kwargs))
    best = float("inf")
    for _ in range(ITERS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> None:
    rng = np.random.default_rng(0)
    # large enough that contraction work (not interpret-loop overhead)
    # dominates: the zero-skipping win is the x vs y*x contraction depth
    p, f = 1024, 512
    y = ops.N_TPU // ops.X_TPU
    results: dict = {"p": p, "f": f, "x": ops.X_TPU, "y": y, "shapes": {}}
    for s in (9, 25, 32):
        divs = jnp.asarray(rng.integers(-7, 8, (p, s)), jnp.int8)
        dkvs = jnp.asarray(rng.integers(-7, 8, (f, s)), jnp.int8)
        # structural model: MXU passes and HBM bytes per output tile
        passes_m1 = -(-s // ops.N_TPU) * f
        passes_m2 = -(-s // ops.X_TPU) * -(-f // y)
        bytes_m1 = p * ops.N_TPU            # padded dense lhs reads
        bytes_m2 = p * ops.X_TPU            # packed lhs read once

        # the pre-PR block-diagonal kernel (now the oracle in ref.py)
        pp = -(-p // 128) * 128
        lhs_pad = jnp.pad(divs, ((0, pp - p), (0, ops.X_TPU - s)))
        rhs_bd = ops.pack_mode2_weights(dkvs, ops.X_TPU, y)  # f=128 aligned
        t_bd = _time(ref.vdpe_pack_gemm_blockdiag, lhs_pad, rhs_bd, y,
                     interpret=True)
        t_zs = _time(ops.mode2_gemm, divs, dkvs, ops.X_TPU, y,
                     interpret=True)
        t_m1 = _time(ops.mode1_gemm, divs, dkvs, interpret=True)
        # fused epilogue vs unfused + separate dequant/bias/relu
        scale = jnp.float32(0.01)
        bias = jnp.asarray(rng.normal(size=(f,)), jnp.float32)
        t_fused = _time(ops.mode2_gemm, divs, dkvs, ops.X_TPU, y,
                        interpret=True, scale=scale, bias=bias, act="relu")

        def unfused(divs, dkvs, scale, bias):
            acc = ops.mode2_gemm(divs, dkvs, ops.X_TPU, y, interpret=True)
            return ref.epilogue_ref(acc, scale, bias[None, :], "relu")

        t_unfused = _time(unfused, divs, dkvs, scale, bias)

        out_zs = ops.mode2_gemm(divs, dkvs, ops.X_TPU, y, interpret=True)
        out_bd = ref.vdpe_pack_gemm_blockdiag(lhs_pad, rhs_bd, y,
                                              interpret=True)[:p, :f]
        assert np.array_equal(np.asarray(out_zs), np.asarray(out_bd))

        row = {
            "mxu_pass_ratio": passes_m1 / passes_m2,
            "lhs_hbm_ratio": bytes_m1 / bytes_m2,
            "contraction_depth_zs": ops.X_TPU,
            "contraction_depth_blockdiag": y * ops.X_TPU,
            "mode2_zs_s": t_zs,
            "mode2_blockdiag_s": t_bd,
            "mode1_s": t_m1,
            "mode2_fused_epilogue_s": t_fused,
            "mode2_unfused_epilogue_s": t_unfused,
        }
        results["shapes"][f"S={s}"] = row
        print(f"kernel,S={s},mxu_pass_ratio={row['mxu_pass_ratio']:.2f},"
              f"lhs_hbm_ratio={row['lhs_hbm_ratio']:.2f},"
              f"zs_s={t_zs:.4f},blockdiag_s={t_bd:.4f},mode1_s={t_m1:.4f},"
              f"fused_s={t_fused:.4f},unfused_s={t_unfused:.4f},"
              f"zs_speedup_vs_blockdiag={t_bd / t_zs:.2f}x")
    OUT_PATH.write_text(json.dumps(results, indent=2) + "\n")
    print(f"kernel_bench,json,{OUT_PATH}")
