"""Pallas kernel micro-bench (interpret mode on CPU): Mode 1 vs Mode 2.

Wall-times in interpret mode are NOT TPU times — the derived metric that
matters is the MXU-pass and HBM-traffic model: Mode-2 packing turns y
small-S contractions into one 128-lane pass and divides input HBM reads
by y (EXPERIMENTS.md §Perf discusses the structural win).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def run() -> None:
    rng = np.random.default_rng(0)
    p, f = 256, 128
    for s in (9, 25, 32):
        divs = jnp.asarray(rng.integers(-7, 8, (p, s)), jnp.int8)
        dkvs = jnp.asarray(rng.integers(-7, 8, (f, s)), jnp.int8)
        y = ops.N_TPU // ops.X_TPU
        # structural model: MXU passes and HBM bytes per output tile
        passes_m1 = -(-s // ops.N_TPU) * f
        passes_m2 = -(-s // ops.X_TPU) * -(-f // y)
        bytes_m1 = p * ops.N_TPU            # padded dense lhs reads
        bytes_m2 = p * ops.X_TPU            # packed lhs read once
        t0 = time.monotonic()
        out2 = ops.mode2_gemm(divs, dkvs, ops.X_TPU, y, interpret=True)
        t2 = time.monotonic() - t0
        t0 = time.monotonic()
        out1 = ops.mode1_gemm(divs, dkvs, interpret=True)
        t1 = time.monotonic() - t0
        assert np.array_equal(np.asarray(out1), np.asarray(out2))
        print(f"kernel,S={s},mxu_pass_ratio={passes_m1 / passes_m2:.2f},"
              f"lhs_hbm_ratio={bytes_m1 / bytes_m2:.2f},"
              f"interp_s_mode1={t1:.3f},interp_s_mode2={t2:.3f}")
