"""Trace-driven closed-loop serving benchmark: Poisson arrivals, mixed CNNs.

Three measurements, all recorded in ``BENCH_serve.json``:

* ``batch_sweep`` — sustained engine throughput at batch 1 vs batch 8 on
  this host (the weight-stationary amortization claim, wall clock), for
  BOTH execution paths: the per-layer Python dispatch loop
  (``engine.forward``, the before) and the whole-model jitted pipeline
  (``engine.forward_jit``, the after) — plus the cycle-true simulator's
  modeled photonic FPS / FPS-per-W at the same batch sizes and
  paper-scale layer tables.  Batch 8 must sustain strictly higher
  images/s than batch 1, and the jitted pipeline must beat the layer
  loop at every batch size (``jit_speedup``).

* ``closed_loop`` — a Poisson arrival trace over the mixed
  EfficientNet/Xception/ShuffleNet serving zoo replayed in wall clock
  against a CNNServer (dynamic batcher, LRU plan registry, whole-model
  jitted dispatch): p50/p99 request latency, sustained images/s,
  per-model splits, pipeline compile stalls, and the modeled hardware
  metrics for every served batch.

Usage:  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [...]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine, serve
from repro.core import simulator as sim

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_serve.json"

MODELS = tuple(serve.SERVING_MODELS)


def _inputs(model: str, n: int, rng: np.random.Generator) -> np.ndarray:
    shape = serve.serving_input_shape(model)
    return rng.normal(size=(n, *shape)).astype(np.float32)


# ---------------------------------------------------------------------------
# batch sweep: wall-clock + modeled amortization
# ---------------------------------------------------------------------------

def batch_sweep(model: str, sizes: Tuple[int, ...] = (1, 8),
                reps: int = 5, seed: int = 0) -> Dict:
    reg = serve.paper_cnn_registry()
    entry = reg.get(model)
    rng = np.random.default_rng(seed)
    wall: Dict[str, float] = {}             # jitted pipeline (the after)
    wall_loop: Dict[str, float] = {}        # per-layer loop (the before)
    jit_speedup: Dict[str, float] = {}
    for bs in sizes:
        xb = jnp.asarray(_inputs(model, bs, rng))

        def _img_per_s(fn) -> float:
            jax.block_until_ready(fn(entry.plan, xb))   # warmup/trace
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(fn(entry.plan, xb))
            return bs * reps / (time.perf_counter() - t0)

        wall_loop[str(bs)] = _img_per_s(engine.forward)
        wall[str(bs)] = _img_per_s(engine.forward_jit)
        jit_speedup[str(bs)] = wall[str(bs)] / wall_loop[str(bs)]
        print(f"serve_bench,batch_sweep_wall,b{bs},"
              f"layer_loop={wall_loop[str(bs)]:.2f} img/s,"
              f"whole_model_jit={wall[str(bs)]:.2f} img/s,"
              f"jit_speedup={jit_speedup[str(bs)]:.2f}x")
    modeled: Dict[str, Dict[str, Dict[str, float]]] = {}
    for p in serve.DEFAULT_HW_POINTS:
        acc = p.to_accelerator()
        modeled[p.label] = {}
        for bs in sizes:
            rep = sim.simulate(acc, entry.sim_specs, batch=bs)
            modeled[p.label][str(bs)] = {
                "fps": rep.fps, "fps_per_watt": rep.fps_per_watt}
            print(f"serve_bench,batch_sweep_model,{p.label},b{bs},"
                  f"fps={rep.fps:.1f},fps_w={rep.fps_per_watt:.2f}")
    return {"model": model, "reps": reps, "wall_images_per_s": wall,
            "wall_images_per_s_layer_loop": wall_loop,
            "jit_speedup": jit_speedup,
            "modeled": modeled,
            "batch8_speedup_wall": (wall[str(sizes[-1])]
                                    / wall[str(sizes[0])])}


# ---------------------------------------------------------------------------
# sharded dispatch: one batch across K simulated accelerator instances
# ---------------------------------------------------------------------------

def dispatch_sweep(model: str, batch: int = 8, fleet_sizes: Tuple[int, ...] = (1, 2, 4),
                   reps: int = 3, seed: int = 0) -> Dict:
    """Shard a fixed batch across K-instance fleets (bitwise-checked).

    Shards now execute concurrently on the dispatcher's thread pool.  Two
    numbers per fleet size:

    * ``images_per_s_wall`` — raw host throughput of the concurrent
      dispatch (report-only: on a small host, K concurrent XLA calls
      share the same cores, so this shows dispatch overhead, not fleet
      scaling);
    * ``images_per_s_paced`` — device-paced throughput, each shard floored
      at the cycle-true simulator's modeled time for that shard at its
      instance's operating point.  This is the fleet-scaling measurement:
      K simulated accelerators genuinely overlap, so fleet=2 must beat
      fleet=1 (``paced_speedup`` — gated in scripts/check_bench.py).
    """
    reg = serve.paper_cnn_registry()
    entry = reg.get(model)
    rng = np.random.default_rng(seed)
    xb = jnp.asarray(_inputs(model, batch, rng))
    single = np.asarray(engine.forward_jit(entry.plan, xb))
    out: Dict = {"model": model, "batch": batch, "fleets": {}}
    paced_base = None
    for k in fleet_sizes:
        fleet = serve.ShardedDispatcher(serve.default_fleet(k))
        res, runs = fleet.run(entry.plan, xb)       # warmup + trace
        if not (np.asarray(res) == single).all():
            raise RuntimeError(
                f"sharded dispatch (K={k}) diverged from single-accelerator")
        t0 = time.perf_counter()
        for _ in range(reps):
            fleet.run(entry.plan, xb)
        wall = batch * reps / (time.perf_counter() - t0)
        fleet.close()
        paced = serve.ShardedDispatcher(serve.default_fleet(k),
                                        pace="hardware")
        paced.run(entry.plan, xb, sim_specs=entry.sim_specs)    # warm memo
        t0 = time.perf_counter()
        for _ in range(reps):
            paced.run(entry.plan, xb, sim_specs=entry.sim_specs)
        wall_paced = batch * reps / (time.perf_counter() - t0)
        paced.close()
        if paced_base is None:
            paced_base = wall_paced
        out["fleets"][str(k)] = {
            "images_per_s_wall": wall,
            "images_per_s_paced": wall_paced,
            "paced_speedup": wall_paced / paced_base,
            "shard_sizes": [r.batch_size for r in runs]}
        print(f"serve_bench,dispatch,K={k},img_per_s={wall:.2f},"
              f"paced_img_per_s={wall_paced:.2f},"
              f"paced_speedup={wall_paced / paced_base:.2f}x,"
              f"shards={[r.batch_size for r in runs]}")
    k2 = out["fleets"].get("2")
    if k2 is not None and k2["paced_speedup"] <= 1.0:
        raise RuntimeError(
            f"device-paced fleet=2 did not beat fleet=1: "
            f"{k2['paced_speedup']:.2f}x")
    # heterogeneous fleet: per-instance modeled costs via telemetry
    het = serve.ShardedDispatcher([
        serve.AcceleratorInstance("rmam1g", serve.OperatingPoint("RMAM", 1.0),
                                  capacity=2.0),
        serve.AcceleratorInstance("rmam5g", serve.OperatingPoint("RMAM", 5.0),
                                  capacity=1.0),
    ])
    res, runs = het.run(entry.plan, xb)
    if not (np.asarray(res) == single).all():
        raise RuntimeError("heterogeneous dispatch diverged")
    log = serve.TelemetryLog(points=serve.DEFAULT_HW_POINTS)
    rec = log.record_batch(
        model=model, sim_specs=entry.sim_specs, batch_size=batch,
        t_formed=0.0, exec_s=sum(r.exec_s for r in runs),
        queue_waits_s=[0.0] * batch, latencies_s=[0.0] * batch,
        shards=[(r.instance.name, r.batch_size, r.instance.hw, r.exec_s)
                for r in runs])
    out["heterogeneous"] = {
        s.instance: {"point": s.point, "frames": s.batch_size,
                     "modeled_fps": s.cost.fps,
                     "modeled_fps_per_watt": s.cost.fps_per_watt}
        for s in rec.shards}
    for s in rec.shards:
        print(f"serve_bench,dispatch_het,{s.instance}@{s.point},"
              f"frames={s.batch_size},modeled_fps={s.cost.fps:.1f}")
    return out


# ---------------------------------------------------------------------------
# closed loop: Poisson trace replayed against the server
# ---------------------------------------------------------------------------

def make_trace(n_requests: int, rate_per_s: float, seed: int,
               ) -> List[Tuple[float, str, np.ndarray]]:
    """Poisson arrivals, models drawn uniformly over the serving zoo."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=n_requests)
    t_arr = np.cumsum(gaps)
    trace = []
    for i in range(n_requests):
        model = MODELS[int(rng.integers(len(MODELS)))]
        trace.append((float(t_arr[i]), model,
                      _inputs(model, 1, rng)[0]))
    return trace


def closed_loop(n_requests: int, rate_per_s: float, max_batch: int,
                max_wait_s: float, seed: int, warm_sizes: bool) -> Dict:
    reg = serve.paper_cnn_registry(capacity=len(MODELS))
    srv = serve.CNNServer(reg, max_batch=max_batch, max_wait_s=max_wait_s)
    if warm_sizes:
        # compile every (model, batch bucket) pipeline up front so the
        # timed loop measures serving, not tracing
        for model in MODELS:
            reg.warm_pipelines(model, max_batch)
    trace = make_trace(n_requests, rate_per_s, seed)
    t_start = time.monotonic()
    i = 0
    while i < len(trace) or srv.pending():
        rel = time.monotonic() - t_start
        while i < len(trace) and trace[i][0] <= rel:
            t_arr, model, x = trace[i]
            srv.submit(model, x, now=t_start + t_arr)
            i += 1
        served = srv.step(force=(i == len(trace)))
        if served == 0 and i < len(trace):
            time.sleep(min(0.0005, max(trace[i][0] - rel, 0.0)))
    summary = srv.telemetry.summary()
    summary["trace"] = {"n_requests": n_requests,
                        "rate_per_s": rate_per_s,
                        "max_batch": max_batch,
                        "max_wait_s": max_wait_s, "seed": seed}
    summary["registry"] = reg.stats()
    summary["pipeline_compile_stalls"] = srv.pipeline_compiles
    print(f"serve_bench,closed_loop,requests={summary['requests']},"
          f"img_per_s={summary['images_per_s_wall']:.2f},"
          f"p50={summary['latency_p50_s'] * 1e3:.1f}ms,"
          f"p99={summary['latency_p99_s'] * 1e3:.1f}ms")
    for model, m in summary["models"].items():
        print(f"serve_bench,closed_loop_model,{model},"
              f"requests={m['requests']},"
              f"mean_batch={m['mean_batch_size']:.2f},"
              f"p99={m['latency_p99_s'] * 1e3:.1f}ms")
    return summary


def run(smoke: bool = True, n_requests: int | None = None,
        rate_per_s: float | None = None, max_batch: int | None = None,
        max_wait_ms: float = 20.0, seed: int = 0) -> Dict:
    if smoke:
        n_requests = n_requests or 18
        rate_per_s = rate_per_s or 30.0
        max_batch = max_batch or 4
    else:
        n_requests = n_requests or 96
        rate_per_s = rate_per_s or 40.0
        max_batch = max_batch or 8
    sweep = batch_sweep(MODELS[0], sizes=(1, 8), reps=3 if smoke else 8,
                        seed=seed)
    dispatch = dispatch_sweep(MODELS[0], batch=8,
                              fleet_sizes=(1, 2) if smoke else (1, 2, 4),
                              reps=2 if smoke else 5, seed=seed)
    loop = closed_loop(n_requests, rate_per_s, max_batch,
                       max_wait_ms / 1e3, seed, warm_sizes=True)
    # merge-write: chaos_bench owns the §fault_tolerance family in the
    # same JSON — preserve foreign sections whichever bench runs first
    out = {}
    if OUT_PATH.exists():
        try:
            out = json.loads(OUT_PATH.read_text())
        except json.JSONDecodeError:
            out = {}
    out.update({"smoke": smoke, "batch_sweep": sweep, "dispatch": dispatch,
                "closed_loop": loop})
    OUT_PATH.write_text(json.dumps(out, indent=2, default=float) + "\n")
    print(f"serve_bench,batch8_speedup_wall,"
          f"{sweep['batch8_speedup_wall']:.2f}x")
    print(f"serve_bench,json,{OUT_PATH}")
    if sweep["batch8_speedup_wall"] <= 1.0:
        raise RuntimeError(
            f"batch 8 did not beat batch 1: {sweep['batch8_speedup_wall']}")
    slow = {b: s for b, s in sweep["jit_speedup"].items() if s <= 1.0}
    if slow:
        raise RuntimeError(
            f"whole-model jit did not beat the layer loop at: {slow}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, n_requests=args.requests, rate_per_s=args.rate,
        max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        seed=args.seed)


if __name__ == "__main__":
    main()
