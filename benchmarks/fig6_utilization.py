"""Paper Fig. 6: per-VDPE MRR utilization across DKV sizes."""
from repro.core.mapping import TPCConfig, vdpe_utilization_for_s

CFGS = {
    "MAM_N44": TPCConfig("MAM", 44, 44, False),
    "AMM_N31": TPCConfig("AMM", 31, 31, False),
    "RMAM_N43": TPCConfig("MAM", 43, 43, True),
    "RAMM_N31": TPCConfig("AMM", 31, 31, True),
}
SIZES = (8, 9, 12, 16, 20, 25, 27, 32, 40, 48, 56, 64, 80, 96, 160,
         192, 224, 288, 384, 480, 640, 960, 1344, 2304, 3840)


def run() -> None:
    for s in SIZES:
        row = ",".join(f"{k}={100 * vdpe_utilization_for_s(c, s):.1f}%"
                       for k, c in CFGS.items())
        print(f"fig6,S={s},{row}")
