"""SDC harness: silent-data-corruption defense scenarios, replayable by seed.

PR-6's chaos harness (chaos_bench.py) injects *availability* faults —
crashes, stragglers, stuck reconfigurations — whose worst case is a late
or missing answer.  This harness injects *integrity* faults that corrupt
the photonic datapath's values in flight (analog PD noise, thermal MRR
detune, stuck weight rings, ADC bit flips) and asserts the three
properties the SDC defense owes its clients:

* **corruption is real** — with the defense off, a corrupting instance
  silently poisons outputs (the ``silent_corruption`` row is the threat
  model, not a regression);
* **detection is near-certain and cheap** — ABFT row/column checksums +
  the accumulation-range guard + the weight-imprint checksum flag
  corrupted shards (``OutputCorrupted``) at >=99% of corrupted
  dispatches, costing <=5% of batch-8 serving throughput;
* **recovery is bitwise** — flagged shards re-execute on healthy
  instances and every admitted request's output is bitwise-identical to
  the fault-free trace; a corrupted-frame-rate SLO sheds (typed) while
  the fleet is poisoned and readmits after quarantine + decay.

Scenarios (recorded under ``BENCH_serve.json["sdc"]`` and gated in
``scripts/check_bench.py``):

* ``silent_corruption`` — defense OFF: analog noise on one instance is
                          served to clients undetected (bitwise=False).
* ``detect_recover``    — defense ON against a 4-kind corruption
                          schedule: detection rate, bitwise recovery,
                          detection latency.
* ``detection_overhead`` — healthy fleet, guarded vs unguarded batch-8
                          serving throughput (the <=5% overhead gate).
* ``canary_sweep``      — persistent stuck-MRR weight corruption with
                          inline checks OFF: per-instance canary probes
                          against golden outputs catch and quarantine
                          the corrupter.
* ``corruption_slo``    — corrupted-frame-rate SLO: typed
                          ``CorruptionBudgetExceeded`` shedding while
                          corruption is live, admission resumes after
                          quarantine + EMA decay.

Usage:  PYTHONPATH=src python -m benchmarks.sdc_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine, serve
from repro.obs.metrics import MetricsRegistry

from .chaos_bench import _bitwise, _inputs, _prewarm, _reference_outputs

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_serve.json"

MODEL = "shufflenet_mini"       # smallest serving-zoo member: fast traces


def _prewarm_guarded(srv: "serve.CNNServer", model: str,
                     policy: "engine.IntegrityPolicy",
                     buckets=(1, 2, 4, 8)) -> None:
    """Compile the guarded pipeline for every shard bucket up front."""
    entry = srv.registry.get(model)
    shape = serve.serving_input_shape(model)
    cargs = engine.null_corruption_args()
    for b in buckets:
        out, _ = engine.forward_jit_guarded(
            entry.plan, jnp.zeros((b, *shape), jnp.float32), cargs=cargs,
            policy=policy)
        jax.block_until_ready(out)


# ---------------------------------------------------------------------------
# scenario: defense off — the threat model
# ---------------------------------------------------------------------------

def silent_corruption(n_requests: int, seed: int) -> Dict:
    """Analog noise on one instance, NO integrity checks: silent poison."""
    xs = _inputs(MODEL, n_requests, seed)
    reference = _reference_outputs(xs)
    injector = serve.FaultInjector([
        serve.FaultEvent("acc0", serve.FaultKind.ANALOG_NOISE, start=0,
                         severity=3.0)])
    fleet = serve.ShardedDispatcher(serve.default_fleet(3),
                                    fault_injector=injector)
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          dispatcher=fleet)
    _prewarm(srv, MODEL)
    rids = [srv.submit(MODEL, x) for x in xs]
    out = srv.run_until_drained()
    fleet.close()
    ok = _bitwise(out, rids, reference)
    row = {
        "bitwise": ok,
        "corrupted_dispatches": injector.corrupted_dispatches,
        "detections": fleet.counters["sdc_detections"],
    }
    assert not ok, ("silent_corruption: analog noise left every output "
                    "bit-identical — the injected fault is a no-op")
    assert fleet.counters["sdc_detections"] == 0
    assert injector.corrupted_dispatches >= 1
    print(f"sdc_bench,silent_corruption,bitwise={ok},"
          f"corrupted={injector.corrupted_dispatches},detections=0")
    return row


# ---------------------------------------------------------------------------
# scenario: defense on — detect every corrupted dispatch, recover bitwise
# ---------------------------------------------------------------------------

def detect_recover(n_requests: int, seed: int) -> Dict:
    """All four corruption kinds across the fleet; ABFT+guards catch them."""
    xs = _inputs(MODEL, n_requests, seed)
    reference = _reference_outputs(xs)
    # one event of each integrity kind, staggered across instances and
    # dispatch windows (a detected corrupter stays quarantined until its
    # window burns down, so fully-overlapping windows would empty the
    # fleet); severities are kind-appropriate and strong enough that a
    # corrupted dispatch always actually perturbs the accumulators
    schedule = [
        serve.FaultEvent("acc0", serve.FaultKind.ANALOG_NOISE, start=1,
                         duration=2, severity=3.0),
        serve.FaultEvent("acc1", serve.FaultKind.THERMAL_DETUNE, start=3,
                         duration=2, severity=0.10),
        serve.FaultEvent("acc2", serve.FaultKind.ADC_BITFLIP, start=5,
                         duration=2, severity=0.01),
        serve.FaultEvent("acc0", serve.FaultKind.STUCK_MRR, start=5,
                         duration=2, severity=2.0),
    ]
    injector = serve.FaultInjector(schedule, seed=seed)
    # generous retry budget: overlapping quarantines can transiently empty
    # the fleet; the dispatcher waits for probes instead of giving up
    fleet = serve.ShardedDispatcher(
        serve.default_fleet(3), fault_injector=injector,
        probe_cooldown_s=0.01, max_retries=8,
        integrity=serve.IntegrityConfig(check_every=1))
    fleet.metrics = MetricsRegistry()
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          dispatcher=fleet)
    _prewarm(srv, MODEL)
    _prewarm_guarded(srv, MODEL, fleet.integrity.policy())
    rids = [srv.submit(MODEL, x) for x in xs]
    out = srv.run_until_drained()
    fleet.close()
    ok = _bitwise(out, rids, reference)
    corrupted = injector.corrupted_dispatches
    detections = fleet.counters["sdc_detections"]
    rate = detections / corrupted if corrupted else 1.0
    hist = fleet.metrics.histogram("serve_sdc_detection_latency_seconds",
                                   model=MODEL)
    row = {
        "bitwise": ok,
        "completed": len(rids),
        "corrupted_dispatches": corrupted,
        "detections": detections,
        "detection_rate": rate,
        "detection_latency_p50_ms": (hist.percentile(0.5) * 1e3
                                     if hist.count else None),
        "counters": dict(fleet.counters),
    }
    assert corrupted >= 3, f"schedule barely fired ({corrupted} dispatches)"
    assert rate >= 0.99, (
        f"detection rate {rate:.3f} < 0.99 "
        f"({detections}/{corrupted} corrupted dispatches flagged)")
    assert ok, "detect_recover: recovered outputs diverged from fault-free"
    assert fleet.counters["quarantines"] >= 1
    print(f"sdc_bench,detect_recover,bitwise={ok},rate={rate:.3f},"
          f"detections={detections}/{corrupted}")
    return row


# ---------------------------------------------------------------------------
# scenario: detection overhead on a healthy fleet
# ---------------------------------------------------------------------------

def detection_overhead(reps: int, seed: int) -> Dict:
    """Guarded vs unguarded batch-8 throughput on a healthy instance."""
    reg = serve.paper_cnn_registry()
    entry = reg.get(MODEL)
    rng = np.random.default_rng(seed)
    xb = jnp.asarray(rng.normal(
        size=(8, *entry.input_shape)).astype(np.float32))

    plain = serve.ShardedDispatcher(serve.default_fleet(1))
    guarded = serve.ShardedDispatcher(
        serve.default_fleet(1),
        integrity=serve.IntegrityConfig(check_every=1))

    def throughput(disp: "serve.ShardedDispatcher") -> float:
        res, _ = disp.run(entry.plan, xb)                       # warm
        t0 = time.perf_counter()
        for _ in range(reps):
            disp.run(entry.plan, xb)
        return 8 * reps / (time.perf_counter() - t0)

    plain_img_s = throughput(plain)
    guarded_img_s = throughput(guarded)
    res_p, _ = plain.run(entry.plan, xb)
    res_g, _ = guarded.run(entry.plan, xb)
    plain.close()
    guarded.close()
    ratio = guarded_img_s / plain_img_s
    row = {
        "bitwise": bool((np.asarray(res_p) == np.asarray(res_g)).all()),
        "plain_images_per_s": plain_img_s,
        "guarded_images_per_s": guarded_img_s,
        "throughput_ratio": ratio,
    }
    assert row["bitwise"], "guarded path diverged on a healthy instance"
    assert ratio >= 0.95, (
        f"integrity checking cost {(1 - ratio) * 100:.1f}% of batch-8 "
        f"throughput (budget: 5%)")
    print(f"sdc_bench,detection_overhead,ratio={ratio:.3f},"
          f"plain={plain_img_s:.1f},guarded={guarded_img_s:.1f}")
    return row


# ---------------------------------------------------------------------------
# scenario: canary probes vs persistent weight corruption
# ---------------------------------------------------------------------------

def canary_sweep(n_requests: int, seed: int) -> Dict:
    """Stuck-MRR weights, inline checks OFF: the canary is the last line."""
    xs = _inputs(MODEL, n_requests, seed)
    reference = _reference_outputs(xs)
    injector = serve.FaultInjector([
        serve.FaultEvent("acc1", serve.FaultKind.STUCK_MRR, start=0,
                         severity=2.0)])
    fleet = serve.ShardedDispatcher(
        serve.default_fleet(3), fault_injector=injector,
        probe_cooldown_s=0.02,
        integrity=serve.IntegrityConfig(check_every=0, canary_every=1))
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          dispatcher=fleet)
    _prewarm(srv, MODEL)
    _prewarm_guarded(srv, MODEL, engine.DISABLED_POLICY, buckets=(1, 2, 4))
    rids = [srv.submit(MODEL, x) for x in xs]
    out = srv.run_until_drained()
    fleet.close()
    ok = _bitwise(out, rids, reference)
    row = {
        "bitwise": ok,
        "canary_probes": fleet.counters["canary_probes"],
        "canary_failures": fleet.counters["canary_failures"],
        "quarantines": fleet.counters["quarantines"],
    }
    assert ok, "canary_sweep: corrupted outputs reached clients"
    assert fleet.counters["canary_failures"] >= 1, (
        "the canary never caught the stuck-MRR instance")
    assert fleet.counters["quarantines"] >= 1
    print(f"sdc_bench,canary_sweep,bitwise={ok},"
          f"probes={fleet.counters['canary_probes']},"
          f"failures={fleet.counters['canary_failures']}")
    return row


# ---------------------------------------------------------------------------
# scenario: corrupted-frame-rate SLO — typed shed, then recovery
# ---------------------------------------------------------------------------

def corruption_slo(seed: int) -> Dict:
    """Shed (typed) while the fleet is poisoned; readmit after decay."""
    halflife = 0.2
    injector = serve.FaultInjector([
        serve.FaultEvent("acc0", serve.FaultKind.ANALOG_NOISE, start=0,
                         duration=2, severity=3.0)])
    fleet = serve.ShardedDispatcher(
        serve.default_fleet(3), fault_injector=injector,
        probe_cooldown_s=0.02,
        integrity=serve.IntegrityConfig(check_every=1))
    slo = serve.ServeSLO(deadline_s=30.0, max_corrupted_frame_rate=0.25,
                         corruption_halflife_s=halflife)
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          dispatcher=fleet, slo=slo)
    _prewarm(srv, MODEL)
    _prewarm_guarded(srv, MODEL, fleet.integrity.policy())
    xs = _inputs(MODEL, 32, seed)
    reference = _reference_outputs(xs)
    admitted_idx: List[int] = []
    rids: List[int] = []

    def submit_burst(lo: int, hi: int) -> int:
        shed = 0
        for i in range(lo, hi):
            try:
                rids.append(srv.submit(MODEL, xs[i]))
                admitted_idx.append(i)
            except serve.CorruptionBudgetExceeded:
                shed += 1
            srv.step(force=True)
        return shed

    # phase 1 — corruption window: detections push the corrupted-frame
    # EMA over budget; the tail of the burst sheds with a typed error
    poisoned_shed = submit_burst(0, 12)
    detections = fleet.counters["sdc_detections"]
    # phase 2 — the fault window has passed and the EMA half-life decays
    # the rate under budget: admission must resume
    time.sleep(4 * halflife)
    recovered_shed = submit_burst(12, 32)
    fleet.close()
    ok = _bitwise(srv.results, rids, [reference[i] for i in admitted_idx])
    row = {
        "bitwise": ok,
        "submitted": 32,
        "admitted": len(rids),
        "poisoned_shed": poisoned_shed,
        "recovered_shed": recovered_shed,
        "detections": detections,
        "integrity_shed": srv.admission["integrity_shed"],
    }
    assert detections >= 1, "corruption window never tripped a detection"
    assert poisoned_shed > 0, "SLO never shed during the poisoned window"
    assert recovered_shed == 0, (
        f"admission never recovered ({recovered_shed} shed after decay)")
    assert ok, "corruption_slo: admitted outputs diverged from fault-free"
    print(f"sdc_bench,corruption_slo,bitwise={ok},"
          f"poisoned_shed={poisoned_shed},recovered_shed={recovered_shed}")
    return row


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

def run(smoke: bool = True, seed: int = 0) -> Dict:
    n = 12 if smoke else 48
    reps = 3 if smoke else 8
    scenarios = {
        "silent_corruption": silent_corruption(n, seed),
        "detect_recover": detect_recover(max(n * 2, 32), seed),
        "detection_overhead": detection_overhead(reps, seed),
        "canary_sweep": canary_sweep(n, seed + 1),
        "corruption_slo": corruption_slo(seed + 2),
    }
    # merge-write: serve_bench/chaos_bench own the other families
    doc = {}
    if OUT_PATH.exists():
        try:
            doc = json.loads(OUT_PATH.read_text())
        except json.JSONDecodeError:
            doc = {}
    doc["sdc"] = {"smoke": smoke, "seed": seed, "scenarios": scenarios}
    OUT_PATH.write_text(json.dumps(doc, indent=2, default=float) + "\n")
    print(f"sdc_bench,json,{OUT_PATH}")
    return scenarios


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small SDC traces for CI")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
