"""Paper Figs. 10-11: area-proportionate FPS and FPS/W (normalized)."""
from repro.cnn.models import MODEL_ZOO, PAPER_CNNS
from repro.core import simulator as sim
from repro.core import tpc

PAPER_GMEANS = {  # RMAM@1G vs X@1G: (FPS ratio, FPS/W ratio)
    "MAM": (1.8, 1.5), "AMM": (17.1, 27.2), "CROSSLIGHT": (65.0, 171.0),
}


def run() -> None:
    tables = {n: MODEL_ZOO[n]() for n in PAPER_CNNS}
    res = sim.evaluate_suite(tables)
    nf = sim.normalized_fps(res)
    nw = sim.normalized_fps_per_watt(res)
    for name in tpc.ACCELERATORS:
        for br in tpc.PAPER_BIT_RATES:
            for cnn in PAPER_CNNS:
                print(f"fig10,{name}@{br:g}Gbps,{cnn},"
                      f"norm_fps={nf[name][br][cnn]:.4f},"
                      f"norm_fps_w={nw[name][br][cnn]:.4f}")
    for other, (f_ref, w_ref) in PAPER_GMEANS.items():
        f = 1 / sim.gmean(nf[other][1.0].values())
        w = 1 / sim.gmean(nw[other][1.0].values())
        print(f"fig10_gmean,RMAM_vs_{other}@1Gbps,"
              f"fps_ratio={f:.2f}(paper {f_ref}),"
              f"fpsw_ratio={w:.2f}(paper {w_ref})")
    ra_f = sim.gmean(nf["RAMM"][1.0].values()) / sim.gmean(
        nf["AMM"][1.0].values())
    print(f"fig10_gmean,RAMM_vs_AMM@1Gbps,fps_ratio={ra_f:.2f}(paper 1.54)")
