"""Paper Figs. 10-11: area-proportionate FPS and FPS/W (normalized).

Also times the full evaluate_suite sweep (4 paper CNNs x 5 accelerators x
paper bit rates) cold and warm — the memoized map_layer/simulate_layer
caches are what make the warm pass cheap — and records both in
``BENCH_fps.json`` (EXPERIMENTS.md §Perf).

The ``reconfiguration`` section is the RCA planner headline: for every
zoo model, the per-layer operating-point planner (engine.search_points)
vs the fixed Mode-1 geometry — modeled FPS, MRR utilization, point-switch
count — the paper reports up to 1.8x FPS from exactly this per-layer
matching (EXPERIMENTS.md §Reconfiguration).

The ``energy`` section is the component-ledger calibration study
(EXPERIMENTS.md §Energy model): per-accelerator power_breakdown rows, the
ledger-exactness residual over the whole sweep, FPS/W-ratio accuracy vs
the paper's Figs. 10-11 gmeans before/after the calibrated knobs
(tpc.DIV_DAC_STATIC_FRACTION, simulator.SUPPLY_POINTS_PER_NS), and the
planner's per-objective EDP/energy on every zoo model.  check_bench gates
the ``fps_w.*`` metric family on this file.
"""
from __future__ import annotations

import json
import math
import time
from pathlib import Path

from repro import engine
from repro.cnn.models import MODEL_ZOO, PAPER_CNNS
from repro.core import mapping
from repro.core import simulator as sim
from repro.core import tpc

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_fps.json"

PAPER_GMEANS = {  # RMAM@1G vs X@1G: (FPS ratio, FPS/W ratio)
    "MAM": (1.8, 1.5), "AMM": (17.1, 27.2), "CROSSLIGHT": (65.0, 171.0),
}

#: the pre-calibration operating point of the energy model, kept as the
#: "before" row of the §Energy-model study: the original knobs
#: (DIV_DAC_STATIC_FRACTION=0.1, SUPPLY_POINTS_PER_NS=516) and the
#: RMAM@1G-vs-X@1G gmean ratios they produced (committed BENCH_fps.json
#: prior to the calibration)
PRE_CALIBRATION = {
    "div_dac_static_fraction": 0.1,
    "supply_points_per_ns": 516.0,
    "ratios": {"MAM": {"fps": 1.658, "fpsw": 1.290},
               "AMM": {"fps": 12.633, "fpsw": 18.019},
               "CROSSLIGHT": {"fps": 117.853, "fpsw": 180.536}},
}


def _log_rms_err(ratios: dict) -> float:
    """Root-mean-square log-space error of the six gmean ratios vs the
    paper's Figs. 10-11 values (the calibration's objective)."""
    errs = []
    for acc, (f_ref, w_ref) in PAPER_GMEANS.items():
        errs.append(math.log(ratios[acc]["fps"] / f_ref) ** 2)
        errs.append(math.log(ratios[acc]["fpsw"] / w_ref) ** 2)
    return math.sqrt(sum(errs) / len(errs))


def run() -> None:
    tables = {n: MODEL_ZOO[n]() for n in PAPER_CNNS}
    # cold: no memoized mappings/schedules at all
    mapping.map_layer.cache_clear()
    sim.simulate_layer.cache_clear()
    t0 = time.perf_counter()
    res = sim.evaluate_suite(tables)
    cold_s = time.perf_counter() - t0
    map_info = mapping.map_layer.cache_info()
    layer_info = sim.simulate_layer.cache_info()
    # warm: every (accelerator, layer) schedule is already cached
    t0 = time.perf_counter()
    sim.evaluate_suite(tables)
    warm_s = time.perf_counter() - t0

    nf = sim.normalized_fps(res)
    nw = sim.normalized_fps_per_watt(res)
    for name in tpc.ACCELERATORS:
        for br in tpc.PAPER_BIT_RATES:
            for cnn in PAPER_CNNS:
                print(f"fig10,{name}@{br:g}Gbps,{cnn},"
                      f"norm_fps={nf[name][br][cnn]:.4f},"
                      f"norm_fps_w={nw[name][br][cnn]:.4f}")
    gmeans = {}
    for other, (f_ref, w_ref) in PAPER_GMEANS.items():
        f = 1 / sim.gmean(nf[other][1.0].values())
        w = 1 / sim.gmean(nw[other][1.0].values())
        gmeans[other] = {"fps_ratio": f, "fps_ratio_paper": f_ref,
                         "fpsw_ratio": w, "fpsw_ratio_paper": w_ref}
        print(f"fig10_gmean,RMAM_vs_{other}@1Gbps,"
              f"fps_ratio={f:.2f}(paper {f_ref}),"
              f"fpsw_ratio={w:.2f}(paper {w_ref})")
    ra_f = sim.gmean(nf["RAMM"][1.0].values()) / sim.gmean(
        nf["AMM"][1.0].values())
    print(f"fig10_gmean,RAMM_vs_AMM@1Gbps,fps_ratio={ra_f:.2f}(paper 1.54)")

    # reconfiguration-aware planner vs fixed geometry, per zoo model
    reconfig = {}
    for name in PAPER_CNNS:
        rep = engine.search_points(tables[name])
        reconfig[name] = {
            "planner_fps": rep.fps,
            "fixed_fps": rep.fixed_fps,
            "fps_uplift": rep.uplift,
            "planner_utilization": rep.mean_utilization,
            "fixed_utilization": rep.fixed_utilization,
            "switches": rep.switches,
            "layers": len(rep.choices),
            "switch_penalty_s": rep.switch_penalty_s,
        }
        print(f"reconfig,{name},planner_fps={rep.fps:.1f},"
              f"fixed_fps={rep.fixed_fps:.1f},uplift={rep.uplift:.2f}x,"
              f"util={rep.fixed_utilization:.2f}->"
              f"{rep.mean_utilization:.2f},switches={rep.switches}")
    uplift_gmean = sim.gmean(
        [r["fps_uplift"] for r in reconfig.values()])
    print(f"reconfig,gmean_fps_uplift,{uplift_gmean:.2f}x(paper: up to 1.8)")

    # -- §Energy model: component ledger + calibration study --------------
    # ledger exactness over the whole sweep: per-layer component rows must
    # reproduce energy_per_frame_j (acceptance bar: 1e-9 relative)
    max_rel = 0.0
    for by_br in res.values():
        for by_cnn in by_br.values():
            for rep in by_cnn.values():
                total = rep.energy_per_frame_j
                attributed = sum(r.energy_j for r in rep.layer_costs())
                max_rel = max(max_rel,
                              abs(attributed - total) / abs(total))
    after = {acc: {"fps": gmeans[acc]["fps_ratio"],
                   "fpsw": gmeans[acc]["fpsw_ratio"]}
             for acc in PAPER_GMEANS}
    accuracy = {}
    for acc, (f_ref, w_ref) in PAPER_GMEANS.items():
        accuracy[acc] = {
            "fps": min(after[acc]["fps"] / f_ref, f_ref / after[acc]["fps"]),
            "fpsw": min(after[acc]["fpsw"] / w_ref,
                        w_ref / after[acc]["fpsw"])}
        print(f"energy_calibration,{acc},fpsw={after[acc]['fpsw']:.2f}"
              f"(paper {w_ref}),accuracy={accuracy[acc]['fpsw']:.3f}")
    err_before = _log_rms_err(PRE_CALIBRATION["ratios"])
    err_after = _log_rms_err(after)
    print(f"energy_calibration,log_rms_err,"
          f"before={err_before:.3f},after={err_after:.3f}")
    print(f"energy_ledger,max_rel_err,{max_rel:.3e}")
    breakdown = {}
    for name in tpc.ACCELERATORS:
        acc = tpc.build_accelerator(name, 1.0)
        breakdown[name] = dict(acc.power_breakdown(),
                               total_static_w=acc.power_static_w(),
                               peak_w=acc.power_w())
    # planner objectives: EDP/energy plans vs the latency plan, per model
    objectives = {}
    for name in PAPER_CNNS:
        by_obj = {o: engine.search_points(tables[name], objective=o)
                  for o in engine.OBJECTIVES}
        objectives[name] = {
            o: {"edp": r.edp, "energy_per_frame_j": r.energy_per_frame_j,
                "fps": r.fps, "avg_power_w": r.avg_power_w,
                "switches": r.switches}
            for o, r in by_obj.items()}
        edp_gain = by_obj["latency"].edp / by_obj["edp"].edp
        print(f"energy_objective,{name},"
              f"edp_vs_latency_plan={edp_gain:.3f}x,"
              f"energy_plan_w={by_obj['energy'].avg_power_w:.1f}")
    energy_section = {
        "calibration": {
            "method": "constrained joint grid fit of "
                      "(tpc.DIV_DAC_STATIC_FRACTION, "
                      "simulator.SUPPLY_POINTS_PER_NS) minimizing the "
                      "log-RMS error of the six Figs. 10-11 gmean ratios, "
                      "subject to the tier-1 fidelity bounds "
                      "(tests/test_simulator.py, tests/test_integration.py)",
            "before": PRE_CALIBRATION,
            "after": {
                "div_dac_static_fraction": tpc.DIV_DAC_STATIC_FRACTION,
                "supply_points_per_ns": sim.SUPPLY_POINTS_PER_NS,
                "ratios": after},
            "log_rms_err_before": err_before,
            "log_rms_err_after": err_after,
            "accuracy": accuracy,
        },
        "ledger_max_rel_err": max_rel,
        "power_breakdown_w": breakdown,
        "objectives": objectives,
    }

    OUT_PATH.write_text(json.dumps({
        "suite": {"cnns": list(PAPER_CNNS),
                  "accelerators": list(tpc.ACCELERATORS),
                  "bit_rates": list(tpc.PAPER_BIT_RATES)},
        "evaluate_suite_cold_s": cold_s,
        "evaluate_suite_warm_s": warm_s,
        "map_layer_cache": {"hits": map_info.hits,
                            "misses": map_info.misses},
        "simulate_layer_cache": {"hits": layer_info.hits,
                                 "misses": layer_info.misses},
        "gmeans_vs_rmam_1g": gmeans,
        "ramm_vs_amm_fps_ratio_1g": ra_f,
        "reconfiguration": dict(reconfig,
                                gmean_fps_uplift=uplift_gmean),
        "energy": energy_section,
    }, indent=2) + "\n")
    print(f"fig10_11,eval_suite_cold_s,{cold_s:.3f}")
    print(f"fig10_11,eval_suite_warm_s,{warm_s:.3f}")
    print(f"fig10_11,json,{OUT_PATH}")
