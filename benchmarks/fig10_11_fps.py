"""Paper Figs. 10-11: area-proportionate FPS and FPS/W (normalized).

Also times the full evaluate_suite sweep (4 paper CNNs x 5 accelerators x
paper bit rates) cold and warm — the memoized map_layer/simulate_layer
caches are what make the warm pass cheap — and records both in
``BENCH_fps.json`` (EXPERIMENTS.md §Perf).

The ``reconfiguration`` section is the RCA planner headline: for every
zoo model, the per-layer operating-point planner (engine.search_points)
vs the fixed Mode-1 geometry — modeled FPS, MRR utilization, point-switch
count — the paper reports up to 1.8x FPS from exactly this per-layer
matching (EXPERIMENTS.md §Reconfiguration).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro import engine
from repro.cnn.models import MODEL_ZOO, PAPER_CNNS
from repro.core import mapping
from repro.core import simulator as sim
from repro.core import tpc

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_fps.json"

PAPER_GMEANS = {  # RMAM@1G vs X@1G: (FPS ratio, FPS/W ratio)
    "MAM": (1.8, 1.5), "AMM": (17.1, 27.2), "CROSSLIGHT": (65.0, 171.0),
}


def run() -> None:
    tables = {n: MODEL_ZOO[n]() for n in PAPER_CNNS}
    # cold: no memoized mappings/schedules at all
    mapping.map_layer.cache_clear()
    sim.simulate_layer.cache_clear()
    t0 = time.perf_counter()
    res = sim.evaluate_suite(tables)
    cold_s = time.perf_counter() - t0
    map_info = mapping.map_layer.cache_info()
    layer_info = sim.simulate_layer.cache_info()
    # warm: every (accelerator, layer) schedule is already cached
    t0 = time.perf_counter()
    sim.evaluate_suite(tables)
    warm_s = time.perf_counter() - t0

    nf = sim.normalized_fps(res)
    nw = sim.normalized_fps_per_watt(res)
    for name in tpc.ACCELERATORS:
        for br in tpc.PAPER_BIT_RATES:
            for cnn in PAPER_CNNS:
                print(f"fig10,{name}@{br:g}Gbps,{cnn},"
                      f"norm_fps={nf[name][br][cnn]:.4f},"
                      f"norm_fps_w={nw[name][br][cnn]:.4f}")
    gmeans = {}
    for other, (f_ref, w_ref) in PAPER_GMEANS.items():
        f = 1 / sim.gmean(nf[other][1.0].values())
        w = 1 / sim.gmean(nw[other][1.0].values())
        gmeans[other] = {"fps_ratio": f, "fps_ratio_paper": f_ref,
                         "fpsw_ratio": w, "fpsw_ratio_paper": w_ref}
        print(f"fig10_gmean,RMAM_vs_{other}@1Gbps,"
              f"fps_ratio={f:.2f}(paper {f_ref}),"
              f"fpsw_ratio={w:.2f}(paper {w_ref})")
    ra_f = sim.gmean(nf["RAMM"][1.0].values()) / sim.gmean(
        nf["AMM"][1.0].values())
    print(f"fig10_gmean,RAMM_vs_AMM@1Gbps,fps_ratio={ra_f:.2f}(paper 1.54)")

    # reconfiguration-aware planner vs fixed geometry, per zoo model
    reconfig = {}
    for name in PAPER_CNNS:
        rep = engine.search_points(tables[name])
        reconfig[name] = {
            "planner_fps": rep.fps,
            "fixed_fps": rep.fixed_fps,
            "fps_uplift": rep.uplift,
            "planner_utilization": rep.mean_utilization,
            "fixed_utilization": rep.fixed_utilization,
            "switches": rep.switches,
            "layers": len(rep.choices),
            "switch_penalty_s": rep.switch_penalty_s,
        }
        print(f"reconfig,{name},planner_fps={rep.fps:.1f},"
              f"fixed_fps={rep.fixed_fps:.1f},uplift={rep.uplift:.2f}x,"
              f"util={rep.fixed_utilization:.2f}->"
              f"{rep.mean_utilization:.2f},switches={rep.switches}")
    uplift_gmean = sim.gmean(
        [r["fps_uplift"] for r in reconfig.values()])
    print(f"reconfig,gmean_fps_uplift,{uplift_gmean:.2f}x(paper: up to 1.8)")

    OUT_PATH.write_text(json.dumps({
        "suite": {"cnns": list(PAPER_CNNS),
                  "accelerators": list(tpc.ACCELERATORS),
                  "bit_rates": list(tpc.PAPER_BIT_RATES)},
        "evaluate_suite_cold_s": cold_s,
        "evaluate_suite_warm_s": warm_s,
        "map_layer_cache": {"hits": map_info.hits,
                            "misses": map_info.misses},
        "simulate_layer_cache": {"hits": layer_info.hits,
                                 "misses": layer_info.misses},
        "gmeans_vs_rmam_1g": gmeans,
        "ramm_vs_amm_fps_ratio_1g": ra_f,
        "reconfiguration": dict(reconfig,
                                gmean_fps_uplift=uplift_gmean),
    }, indent=2) + "\n")
    print(f"fig10_11,eval_suite_cold_s,{cold_s:.3f}")
    print(f"fig10_11,eval_suite_warm_s,{warm_s:.3f}")
    print(f"fig10_11,json,{OUT_PATH}")
