"""Analog-noise ablation: Eq. 9/10 PD noise vs bit precision (4-bit design).

The paper fixes 4-bit precision because Eq. 9's SNR budget collapses above
it (Sec. III-B). This benchmark injects the photodetector noise at the
summation elements and reports the integer-domain RMS error of VDP results
per (bits, BR) — the 4-bit/1-Gbps operating point stays well under
``FLOOR_LSB`` RMS while higher precisions either blow past their own LSB
or are flat-out infeasible under the SNR budget
(``core.photonics.InfeasiblePrecisionError``, reported as
``feasible: false`` rows rather than silently-clean results).

The table is merge-written into ``BENCH_kernels.json["analog_noise"]``
(kernel_bench owns the other families in the same JSON) and the
4-bit/1-Gbps RMS floor is gated in ``scripts/check_bench.py``.

Usage:  PYTHONPATH=src python -m benchmarks.noise_ablation
"""
import json
from pathlib import Path
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vdp
from repro.core.mapping import TPCConfig
from repro.core.photonics import InfeasiblePrecisionError

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_kernels.json"

RMAM = TPCConfig("MAM", 43, 43, True)

#: the design point's noise budget: 4-bit/1-Gbps must stay under this
#: integer-domain RMS (in LSBs) for the paper's precision choice to hold
FLOOR_LSB = 1.5


def run() -> Dict:
    rng = np.random.default_rng(0)
    divs = jnp.asarray(rng.integers(-7, 8, (256, 43)), jnp.int8)
    dkvs = jnp.asarray(rng.integers(-7, 8, (16, 43)), jnp.int8)
    clean = np.asarray(vdp.sliced_vdp_gemm(divs, dkvs, RMAM), np.float64)
    rows: Dict[str, Dict] = {}
    for bits in (2, 4, 6, 8):
        for br in (1e9, 5e9):
            key = f"b{bits}_br{br / 1e9:g}"
            row: Dict = {"bits": bits, "br_gbps": br / 1e9}
            try:
                noisy = vdp.noisy_vdp_gemm(jax.random.PRNGKey(1), divs,
                                           dkvs, RMAM, br_hz=br, bits=bits)
            except InfeasiblePrecisionError as e:
                row.update(feasible=False, reason=str(e))
                print(f"noise,bits={bits},br={br / 1e9:g}Gbps,infeasible")
            else:
                err = np.asarray(noisy, np.float64) - clean
                rms = float(np.sqrt(np.mean(err ** 2)))
                row.update(feasible=True, rms_lsb=rms)
                print(f"noise,bits={bits},br={br / 1e9:g}Gbps,"
                      f"rms_lsb={rms:.3f}")
            rows[key] = row
    design = rows["b4_br1"]
    assert design["feasible"], "the paper's 4-bit/1-Gbps point must work"
    assert design["rms_lsb"] <= FLOOR_LSB, (
        f"4-bit/1-Gbps RMS noise {design['rms_lsb']:.3f} LSB blew the "
        f"{FLOOR_LSB} LSB design budget")
    # merge-write: kernel_bench owns the other families in the same JSON
    doc = {}
    if OUT_PATH.exists():
        try:
            doc = json.loads(OUT_PATH.read_text())
        except json.JSONDecodeError:
            doc = {}
    doc["analog_noise"] = {"rows": rows, "floor_lsb_b4_br1": FLOOR_LSB}
    OUT_PATH.write_text(json.dumps(doc, indent=2, default=float) + "\n")
    print(f"noise_ablation,json,{OUT_PATH}")
    return rows


if __name__ == "__main__":
    run()
