"""Analog-noise ablation: Eq. 9/10 PD noise vs bit precision (4-bit design).

The paper fixes 4-bit precision because Eq. 9's SNR budget collapses above
it (Sec. III-B). This benchmark injects the photodetector noise at the
summation elements and reports the integer-domain RMS error of VDP results
per (bits, BR) — the 4-bit/1-Gbps operating point stays ~1 LSB while
higher precisions blow past their own LSB, reproducing the design logic.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vdp
from repro.core.mapping import TPCConfig

RMAM = TPCConfig("MAM", 43, 43, True)


def run() -> None:
    rng = np.random.default_rng(0)
    divs = jnp.asarray(rng.integers(-7, 8, (256, 43)), jnp.int8)
    dkvs = jnp.asarray(rng.integers(-7, 8, (16, 43)), jnp.int8)
    clean = np.asarray(vdp.sliced_vdp_gemm(divs, dkvs, RMAM), np.float64)
    for bits in (2, 4, 6):
        for br in (1e9, 5e9):
            noisy = vdp.noisy_vdp_gemm(jax.random.PRNGKey(1), divs, dkvs,
                                       RMAM, br_hz=br, bits=bits)
            err = np.asarray(noisy, np.float64) - clean
            rms = float(np.sqrt(np.mean(err ** 2)))
            print(f"noise,bits={bits},br={br/1e9:g}Gbps,rms_lsb={rms:.3f}")
