"""Paper Table VIII: area-proportionate VDPE counts (ours vs paper)."""
from repro.core import tpc


def run() -> None:
    for br in tpc.PAPER_BIT_RATES:
        ours = tpc.area_proportionate_counts(br)
        for name in tpc.ACCELERATORS:
            paper = tpc.PAPER_TABLE_VIII[name][br]
            print(f"table8,{name}@{br:g}Gbps,ours={ours[name]},"
                  f"paper={paper}")
        for name in tpc.ACCELERATORS:
            acc = tpc.build_accelerator(name, br)
            print(f"table8_power,{name}@{br:g}Gbps,"
                  f"static_w={acc.power_static_w():.1f},"
                  f"area_mm2={acc.area_mm2():.1f}")
