"""Chaos harness: fault-injected serving scenarios, replayable by seed.

Every scenario drives the real serving stack (CNNServer -> concurrent
ShardedDispatcher -> whole-model jitted pipeline) against an injected
photonic failure and asserts the two properties a fault-tolerant fleet
owes its clients:

* **correctness is non-negotiable** — outputs of every admitted request
  are bitwise-identical to the healthy single-accelerator run, no matter
  which instances crashed, straggled, or got re-dealt mid-trace;
* **degradation is graceful and typed** — overload on a degraded fleet is
  shed at the door with ``AdmissionRejected`` (never a blown p99 or a
  stack trace), and the fleet readmits itself once quarantine probes
  pass.

Scenarios (all recorded under ``BENCH_serve.json["fault_tolerance"]`` and
gated in ``scripts/check_bench.py``):

* ``healthy_baseline``   — the same trace and fleet with zero injected
                           faults: the reference row for the chaos table.
* ``kill_mid_trace``     — one of three instances crashes permanently
                           mid-trace; retries re-apportion its frames.
* ``straggler_storm``    — two instances hang past the shard deadline;
                           timeouts quarantine them, the survivor carries
                           the trace, stragglers readmit when the storm
                           passes.
* ``full_fleet_recovery`` — 2-of-3 instances stick mid-reconfiguration
                           under a burst: SLO admission control sheds the
                           excess (typed), probes readmit the fleet, and
                           a later burst is fully admitted again.
* ``concurrent_vs_sequential`` — device-paced fleet=2 concurrent dispatch
                           vs the same shards run sequentially (the old
                           regression): concurrency must win.

Usage:  PYTHONPATH=src python -m benchmarks.chaos_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine, serve

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_serve.json"

MODEL = "shufflenet_mini"       # smallest serving-zoo member: fast chaos
#: the SLO scenario serves the model with the *heaviest* paper-scale
#: simulator table instead: its modeled per-frame time (~7 ms at RMAM@1G)
#: dominates host jitter, so the paced admission math is reproducible
SLO_MODEL = "efficientnet_mini"


def _inputs(model: str, n: int, seed: int) -> np.ndarray:
    shape = serve.serving_input_shape(model)
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, *shape)).astype(np.float32)


def _reference_outputs(xs: np.ndarray, model: str = MODEL,
                       ) -> List[np.ndarray]:
    """Healthy single-accelerator outputs, one per input (the oracle)."""
    reg = serve.paper_cnn_registry()
    srv = serve.CNNServer(reg, max_batch=4)
    rids = [srv.submit(model, x) for x in xs]
    out = srv.run_until_drained()
    return [out[r] for r in rids]


def _bitwise(result: Dict[int, np.ndarray], rids: List[int],
             reference: List[np.ndarray]) -> bool:
    return all((result[r] == ref).all() for r, ref in zip(rids, reference))


def _prewarm(srv: "serve.CNNServer", model: str,
             buckets: Tuple[int, ...] = (1, 2, 4)) -> None:
    """Compile the model's pipeline for every shard bucket up front.

    Chaos scenarios measure serving behavior, not XLA trace time: a
    multi-second compile stall inside an 80 ms shard deadline would read
    as a straggler and quarantine a perfectly healthy instance, and a
    compile-inflated service-rate EMA would skew the SLO sizing.
    """
    entry = srv.registry.get(model)
    shape = serve.serving_input_shape(model)
    for b in buckets:
        jax.block_until_ready(
            engine.forward_jit(entry.plan,
                               jnp.zeros((b, *shape), jnp.float32)))


# ---------------------------------------------------------------------------
# scenario: zero faults (the reference row)
# ---------------------------------------------------------------------------

def healthy_baseline(n_requests: int, seed: int) -> Dict:
    """Same trace and fleet shape as kill_mid_trace, no injector."""
    xs = _inputs(MODEL, n_requests, seed)
    reference = _reference_outputs(xs)
    fleet = serve.ShardedDispatcher(serve.default_fleet(3))
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          dispatcher=fleet)
    _prewarm(srv, MODEL)
    # one warm dispatched batch: this scenario runs first in the harness,
    # so it would otherwise absorb the pool spin-up + first-dispatch cost
    # the fault scenarios never pay
    for x in _inputs(MODEL, 4, seed + 1):
        srv.submit(MODEL, x)
    srv.run_until_drained()
    srv.reset()
    t0 = time.perf_counter()
    rids = [srv.submit(MODEL, x) for x in xs]
    out = srv.run_until_drained()
    wall = time.perf_counter() - t0
    fleet.close()
    summ = srv.telemetry.summary()
    ok = _bitwise(out, rids, reference)
    row = {
        "bitwise": ok,
        "completed": len(rids),
        "submitted": n_requests,
        "images_per_s_wall": n_requests / wall,
        "p99_ms": summ["latency_p99_s"] * 1e3,
        "counters": dict(fleet.counters),
    }
    assert ok, "healthy_baseline: outputs diverged from healthy run"
    assert fleet.counters["retries"] == 0
    assert fleet.counters["quarantines"] == 0
    print(f"chaos_bench,healthy_baseline,bitwise={ok},"
          f"img_per_s={row['images_per_s_wall']:.1f}")
    return row


# ---------------------------------------------------------------------------
# scenario: kill an instance mid-trace
# ---------------------------------------------------------------------------

def kill_mid_trace(n_requests: int, seed: int) -> Dict:
    xs = _inputs(MODEL, n_requests, seed)
    reference = _reference_outputs(xs)
    injector = serve.FaultInjector([
        serve.FaultEvent("acc1", serve.FaultKind.CRASH, start=2)])
    fleet = serve.ShardedDispatcher(serve.default_fleet(3),
                                    fault_injector=injector,
                                    probe_cooldown_s=0.02)
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          dispatcher=fleet)
    _prewarm(srv, MODEL)
    t0 = time.perf_counter()
    rids = [srv.submit(MODEL, x) for x in xs]
    out = srv.run_until_drained()
    wall = time.perf_counter() - t0
    fleet.close()
    summ = srv.telemetry.summary()
    ok = _bitwise(out, rids, reference)
    row = {
        "bitwise": ok,
        "completed": len(rids),
        "submitted": n_requests,
        "images_per_s_wall": n_requests / wall,
        "p99_ms": summ["latency_p99_s"] * 1e3,
        "counters": dict(fleet.counters),
        "killed_state": summ["fleet"]["instances"]["acc1"]["state"],
    }
    assert ok, "kill_mid_trace: outputs diverged from healthy run"
    assert fleet.counters["retries"] >= 1, "crash never tripped a retry"
    assert fleet.counters["quarantines"] >= 1
    assert row["killed_state"] == "quarantined"
    print(f"chaos_bench,kill_mid_trace,bitwise={ok},"
          f"retries={fleet.counters['retries']},"
          f"img_per_s={row['images_per_s_wall']:.1f}")
    return row


# ---------------------------------------------------------------------------
# scenario: straggler storm (deadline-driven timeouts)
# ---------------------------------------------------------------------------

def straggler_storm(n_requests: int, seed: int) -> Dict:
    xs = _inputs(MODEL, n_requests, seed)
    reference = _reference_outputs(xs)
    # two of three instances hang well past the shard deadline for a
    # couple of dispatches each, then recover
    injector = serve.FaultInjector([
        serve.FaultEvent("acc0", serve.FaultKind.STRAGGLE, start=1,
                         duration=2, severity=0.30),
        serve.FaultEvent("acc1", serve.FaultKind.STRAGGLE, start=2,
                         duration=2, severity=0.30)])
    fleet = serve.ShardedDispatcher(serve.default_fleet(3),
                                    fault_injector=injector,
                                    deadline_s=0.08,
                                    probe_cooldown_s=0.02)
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          dispatcher=fleet)
    _prewarm(srv, MODEL)
    t0 = time.perf_counter()
    rids = [srv.submit(MODEL, x) for x in xs]
    out = srv.run_until_drained()
    wall = time.perf_counter() - t0
    # give the storm time to pass, then confirm the fleet self-heals
    deadline = time.perf_counter() + 5.0
    while (len(fleet.active_instances()) < 3
           and time.perf_counter() < deadline):
        time.sleep(0.02)
    healed = len(fleet.active_instances())
    fleet.close()
    summ = srv.telemetry.summary()
    ok = _bitwise(out, rids, reference)
    row = {
        "bitwise": ok,
        "completed": len(rids),
        "submitted": n_requests,
        "images_per_s_wall": n_requests / wall,
        "p99_ms": summ["latency_p99_s"] * 1e3,
        "counters": dict(fleet.counters),
        "healed_instances": healed,
    }
    assert ok, "straggler_storm: outputs diverged from healthy run"
    assert fleet.counters["timeouts"] >= 1, "no shard ever timed out"
    assert healed == 3, f"fleet never healed (healthy={healed}/3)"
    assert fleet.counters["readmissions"] >= 1
    print(f"chaos_bench,straggler_storm,bitwise={ok},"
          f"timeouts={fleet.counters['timeouts']},"
          f"readmissions={fleet.counters['readmissions']}")
    return row


# ---------------------------------------------------------------------------
# scenario: 2-of-3 loss under load -> shed, probe, readmit
# ---------------------------------------------------------------------------

def full_fleet_recovery(seed: int) -> Dict:
    # decay traffic is sized in *batches* (EMA updates once per served
    # batch of 4): 6 updates shrink the retry-inflated EMA by 0.7^6, and
    # the final burst is shallow enough that even a 3x-of-warm residual
    # EMA keeps its tail inside the deadline
    warm_n, trip_n, storm_n, decay_n, burst_n = 8, 4, 24, 24, 8
    xs = _inputs(SLO_MODEL,
                 warm_n + trip_n + storm_n + decay_n + burst_n, seed)
    reference = _reference_outputs(xs, SLO_MODEL)
    injector = serve.FaultInjector([
        serve.FaultEvent("acc0", serve.FaultKind.STUCK_RECONFIG, start=2,
                         duration=6),
        serve.FaultEvent("acc1", serve.FaultKind.STUCK_RECONFIG, start=2,
                         duration=6)])
    # paced on modeled device time: the admission estimator's EMA then
    # tracks the (stable) photonic service rate instead of 1-core host
    # jitter, so the shed/admit boundary is reproducible across hosts
    fleet = serve.ShardedDispatcher(serve.default_fleet(3),
                                    fault_injector=injector,
                                    probe_cooldown_s=0.02,
                                    backoff_base_s=0.005,
                                    pace="hardware")
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          dispatcher=fleet)
    _prewarm(srv, SLO_MODEL)
    admitted_idx: List[int] = []
    rids: List[int] = []
    cursor = 0

    def submit_burst(n: int) -> int:
        nonlocal cursor
        shed = 0
        for _ in range(n):
            try:
                rids.append(srv.submit(SLO_MODEL, xs[cursor]))
                admitted_idx.append(cursor)
            except serve.AdmissionRejected:
                shed += 1
            cursor += 1
        srv.run_until_drained()
        return shed

    # phase 1 — healthy warmup: establishes the service-rate EMA the
    # admission estimator runs on, then sizes the SLO from the measurement
    submit_burst(warm_n)
    ema = srv._frame_s_ema
    # deadline sized so the healthy fleet absorbs any burst here with 2x+
    # headroom, while the 3x drain-time penalty of a 1/3-capacity fleet
    # pushes a deep burst's tail past it
    srv.slo = serve.ServeSLO(deadline_s=40 * ema, min_observations=1)
    # phase 2a — tripwire: the next batch hits the stuck window on acc0
    # and acc1 (their 3rd dispatch); both quarantine, the retry lands the
    # frames on acc2, and the fleet drops to 1/3 capacity
    submit_burst(trip_n)
    assert len(fleet.active_instances()) == 1, "fault never tripped"
    # phase 2b — burst against the degraded fleet: the admission
    # estimator sees 3x the drain time and sheds the tail with a typed
    # error instead of queueing it to blow the deadline
    degraded_shed = submit_burst(storm_n)
    degraded_counters = dict(fleet.counters)
    # phase 3 — probes burn down the stuck window; wait for readmission
    deadline = time.perf_counter() + 5.0
    while (len(fleet.active_instances()) < 3
           and time.perf_counter() < deadline):
        time.sleep(0.02)
    healed = len(fleet.active_instances())
    # phase 4 — decay the retry-inflated EMA with healthy traffic, then a
    # deep burst must be admitted in full again
    submit_burst(decay_n)
    recovered_shed = submit_burst(burst_n)
    fleet.close()
    ok = _bitwise(srv.results, rids,
                  [reference[i] for i in admitted_idx])
    row = {
        "bitwise": ok,
        "submitted": cursor,
        "admitted": len(rids),
        "degraded_shed": degraded_shed,
        "recovered_shed": recovered_shed,
        "healed_instances": healed,
        "slo_deadline_ms": srv.slo.deadline_s * 1e3,
        "counters": degraded_counters,
        "admission": dict(srv.admission),
    }
    assert ok, "full_fleet_recovery: admitted outputs diverged"
    assert degraded_shed > 0, "2-of-3 loss under load never shed"
    assert recovered_shed == 0, (
        f"recovered fleet still shedding ({recovered_shed}): "
        f"ema={srv._frame_s_ema * 1e3:.3f}ms warm_ema={ema * 1e3:.3f}ms "
        f"deadline={srv.slo.deadline_s * 1e3:.1f}ms "
        f"frac={fleet.healthy_capacity_fraction():.2f}")
    assert healed == 3, f"fleet never readmitted (healthy={healed}/3)"
    assert fleet.counters["readmissions"] >= 2
    print(f"chaos_bench,full_fleet_recovery,bitwise={ok},"
          f"degraded_shed={degraded_shed},recovered_shed={recovered_shed},"
          f"readmissions={fleet.counters['readmissions']}")
    return row


# ---------------------------------------------------------------------------
# scenario: concurrent vs sequential dispatch (the reversed regression)
# ---------------------------------------------------------------------------

def concurrent_vs_sequential(reps: int, seed: int) -> Dict:
    model = "efficientnet_mini"
    reg = serve.paper_cnn_registry()
    entry = reg.get(model)
    rng = np.random.default_rng(seed)
    xb = jnp.asarray(rng.normal(
        size=(8, *entry.input_shape)).astype(np.float32))
    single = np.asarray(engine.forward_jit(entry.plan, xb))

    conc = serve.ShardedDispatcher(serve.default_fleet(2), pace="hardware")
    res, runs = conc.run(entry.plan, xb, sim_specs=entry.sim_specs)  # warm
    assert (np.asarray(res) == single).all()
    t0 = time.perf_counter()
    for _ in range(reps):
        conc.run(entry.plan, xb, sim_specs=entry.sim_specs)
    conc_img_s = 8 * reps / (time.perf_counter() - t0)

    # sequential reference: identical shard split + device pacing, but the
    # shards run one after the other — the pre-concurrency dispatcher
    sizes = conc.shard_sizes(8)
    insts = conc.instances

    def sequential_once() -> None:
        start = 0
        for inst, size in zip(insts, sizes):
            if size == 0:
                continue
            t_shard = time.perf_counter()
            jax.block_until_ready(
                engine.forward_jit(entry.plan, xb[start:start + size]))
            floor = conc._modeled_shard_s(inst, tuple(entry.sim_specs),
                                          size)
            rest = floor - (time.perf_counter() - t_shard)
            if rest > 0:
                time.sleep(rest)
            start += size

    sequential_once()                                   # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        sequential_once()
    seq_img_s = 8 * reps / (time.perf_counter() - t0)
    conc.close()
    speedup = conc_img_s / seq_img_s
    row = {
        "bitwise": True,
        "fleet": 2,
        "concurrent_images_per_s": conc_img_s,
        "sequential_images_per_s": seq_img_s,
        "concurrent_speedup": speedup,
    }
    assert speedup > 1.0, (
        f"concurrent fleet=2 dispatch did not beat sequential "
        f"({speedup:.2f}x)")
    print(f"chaos_bench,concurrent_vs_sequential,"
          f"conc={conc_img_s:.1f},seq={seq_img_s:.1f},"
          f"speedup={speedup:.2f}x")
    return row


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

def run(smoke: bool = True, seed: int = 0) -> Dict:
    n = 12 if smoke else 48
    reps = 3 if smoke else 8
    scenarios = {
        "healthy_baseline": healthy_baseline(n, seed),
        "kill_mid_trace": kill_mid_trace(n, seed),
        "straggler_storm": straggler_storm(n, seed + 1),
        "full_fleet_recovery": full_fleet_recovery(seed + 2),
        "concurrent_vs_sequential": concurrent_vs_sequential(reps, seed),
    }
    # merge-write: serve_bench owns the other families in the same JSON
    doc = {}
    if OUT_PATH.exists():
        try:
            doc = json.loads(OUT_PATH.read_text())
        except json.JSONDecodeError:
            doc = {}
    doc["fault_tolerance"] = {"smoke": smoke, "seed": seed,
                              "scenarios": scenarios}
    OUT_PATH.write_text(json.dumps(doc, indent=2, default=float) + "\n")
    print(f"chaos_bench,json,{OUT_PATH}")
    return scenarios


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small chaos traces for CI")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
