"""Paper Table III: DKV-size census of EfficientNet-B7."""
from repro.cnn.layers import dkv_census
from repro.cnn.models import efficientnet


def run() -> None:
    for kind, (k, _, d), f, s in dkv_census(efficientnet("B7")):
        print(f"table3,{kind},K={k},D={d},F={f},S={s}")
