"""Observability bench: traced chaos serving + tracing-overhead gate.

Two measurements, recorded under ``BENCH_serve.json["observability"]`` and
gated in ``scripts/check_bench.py``:

* ``traced_chaos`` — drives the real fault-injected serving stack
  (CNNServer -> concurrent ShardedDispatcher, crash + thermal-drift
  schedule) with the span tracer enabled, then exports the dual-clock
  Chrome trace (host wall time next to modeled photonic hardware time,
  per fleet instance) to ``experiments/obs/chaos_trace.json`` and the
  metrics snapshot to ``experiments/obs/metrics.json``.  Asserts the
  trace validates against the event schema, carries per-shard spans and
  fault instants on both clocks, and that ``summary()["layers"]``
  attributes >= 95% of the modeled time to named layers.
* ``overhead`` — the same single-instance serving trace back-to-back with
  tracing disabled (the no-op path) and enabled; the throughput ratio
  enabled/disabled is the ``obs.overhead.ratio`` metric check_bench
  floors at 0.95.

Usage:  PYTHONPATH=src python -m benchmarks.obs_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict

import numpy as np

from repro import obs, serve

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_serve.json"
OBS_DIR = REPO_ROOT / "experiments" / "obs"
TRACE_PATH = OBS_DIR / "chaos_trace.json"
METRICS_PATH = OBS_DIR / "metrics.json"

MODEL = "shufflenet_mini"       # smallest serving-zoo member


def _inputs(model: str, n: int, seed: int) -> np.ndarray:
    shape = serve.serving_input_shape(model)
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, *shape)).astype(np.float32)


def _drain(srv: "serve.CNNServer", xs: np.ndarray) -> float:
    t0 = time.perf_counter()
    for x in xs:
        srv.submit(MODEL, x)
    srv.run_until_drained()
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# traced chaos trace -> dual-clock Perfetto export
# ---------------------------------------------------------------------------

def traced_chaos(n_requests: int, seed: int) -> Dict:
    """Serve a fault-injected trace with tracing on; export both clocks."""
    xs = _inputs(MODEL, n_requests, seed)
    tracer = obs.Tracer(capacity=65536)
    injector = serve.FaultInjector([
        serve.FaultEvent("acc1", serve.FaultKind.CRASH, start=2,
                         duration=3),
        serve.FaultEvent("acc2", serve.FaultKind.THERMAL_DRIFT, start=1,
                         duration=2, severity=0.005)])
    fleet = serve.ShardedDispatcher(serve.default_fleet(3),
                                    fault_injector=injector,
                                    probe_cooldown_s=0.02)
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          dispatcher=fleet, tracer=tracer)
    # prewarm compiles outside the trace: the spans should show serving,
    # not XLA trace time
    warm = _inputs(MODEL, 4, seed + 1)
    _drain(srv, warm)
    srv.reset()
    tracer.clear()
    wall = _drain(srv, xs)
    fleet.close()

    OBS_DIR.mkdir(parents=True, exist_ok=True)
    records = tracer.events()
    doc = obs.write_trace(TRACE_PATH, records)
    n_events = obs.validate_chrome_trace(doc, require_dual_clock=True)
    census = obs.category_census(records)
    summ = srv.telemetry.summary()
    METRICS_PATH.write_text(
        json.dumps(srv.telemetry.metrics.snapshot(), indent=2) + "\n")

    layers = summ["layers"][MODEL]
    occupancy = obs.hw_occupancy(doc)
    row = {
        "completed": summ["requests"],
        "submitted": n_requests,
        "images_per_s_wall": n_requests / wall,
        "trace_events": n_events,
        "trace_path": str(TRACE_PATH.relative_to(REPO_ROOT)),
        "metrics_path": str(METRICS_PATH.relative_to(REPO_ROOT)),
        "category_census": census,
        "shard_spans": census.get("shard", 0),
        "fault_instants": census.get("fault", 0),
        "hw_busy_s": occupancy,
        "tracer": tracer.stats(),
        "layers_coverage": layers["coverage"],
        "top_hotspots": layers["top"],
        "counters": dict(fleet.counters),
    }
    assert summ["requests"] == n_requests, "trace did not drain"
    assert row["shard_spans"] > 0, "no per-shard spans recorded"
    assert row["fault_instants"] > 0, "injected faults left no instants"
    assert census.get("request", 0) >= 2 * n_requests, (
        "request async begin/end pairs missing")
    assert occupancy, "no modeled hardware occupancy exported"
    assert layers["coverage"] >= 0.95, (
        f"per-layer attribution covers only {layers['coverage']:.3f} "
        f"of the modeled time")
    print(f"obs_bench,traced_chaos,events={n_events},"
          f"shard_spans={row['shard_spans']},"
          f"faults={row['fault_instants']},"
          f"coverage={layers['coverage']:.4f}")
    return row


# ---------------------------------------------------------------------------
# tracing overhead: enabled vs disabled serving throughput
# ---------------------------------------------------------------------------

def overhead(n_requests: int, rounds: int, seed: int) -> Dict:
    """Enabled-vs-disabled serving throughput on the no-dispatcher path.

    Both servers share one registry (and therefore one set of compiled
    pipelines); each round serves the same trace disabled then enabled.
    The gated ratio divides *best-of-rounds* throughputs: host noise
    (scheduler hiccups, other tenants) only ever adds wall time, so the
    minimum wall time per mode is the low-noise estimate of what each
    path actually costs — medians of interleaved rounds still swung
    +-14% on shared hosts, far past the 5% overhead bar.
    """
    reg = serve.paper_cnn_registry()
    xs = _inputs(MODEL, n_requests, seed)
    srv_off = serve.CNNServer(reg, max_batch=8)
    srv_on = serve.CNNServer(reg, max_batch=8, tracer=obs.Tracer())
    # warm both servers through the shared compiled pipeline
    for srv in (srv_off, srv_on):
        _drain(srv, xs[: min(8, len(xs))])
        srv.reset()
    off_s, on_s = [], []
    for _ in range(rounds):
        off_s.append(_drain(srv_off, xs))
        srv_off.reset()
        on_s.append(_drain(srv_on, xs))
        srv_on.reset()
        srv_on.tracer.clear()
    off_img_s = n_requests / min(off_s)
    on_img_s = n_requests / min(on_s)
    ratio = on_img_s / off_img_s
    row = {
        "disabled_images_per_s": off_img_s,
        "enabled_images_per_s": on_img_s,
        "ratio": ratio,
        "rounds": rounds,
        "requests_per_round": n_requests,
    }
    print(f"obs_bench,overhead,disabled={off_img_s:.1f},"
          f"enabled={on_img_s:.1f},ratio={ratio:.4f}")
    return row


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

def run(smoke: bool = True, seed: int = 0) -> Dict:
    n = 12 if smoke else 48
    rounds = 3 if smoke else 7
    results = {
        "traced_chaos": traced_chaos(n, seed),
        "overhead": overhead(4 * n, rounds, seed),
    }
    # merge-write: serve_bench/chaos_bench own the other families here
    doc = {}
    if OUT_PATH.exists():
        try:
            doc = json.loads(OUT_PATH.read_text())
        except json.JSONDecodeError:
            doc = {}
    doc["observability"] = dict(results, smoke=smoke, seed=seed)
    OUT_PATH.write_text(json.dumps(doc, indent=2, default=float) + "\n")
    print(f"obs_bench,json,{OUT_PATH}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small traces for CI")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
