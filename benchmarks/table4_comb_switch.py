"""Paper Table IV: comb-switch FSR / radius / pair-count designs."""
from repro.core import photonics as ph
from repro.core import scalability as sc


def run() -> None:
    for variant, rows in sc.PAPER_TABLE_IV.items():
        for br, (n, fsr_ref, radius_ref, y_ref) in rows.items():
            d = ph.design_comb_switch(n)
            print(f"table4,{variant}@{br:g}Gbps,N={n},y={d.y}(paper {y_ref}),"
                  f"fsr={d.cs_fsr_nm:.2f}nm(paper {fsr_ref}),"
                  f"radius={d.radius_um:.2f}um(paper {radius_ref})")
