"""Benchmark harness: one entry per paper table/figure.

Prints ``name,value,derived`` CSV rows per benchmark plus wall time.
Usage:  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--skip NAME]
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (chaos_bench, fig4_5_scalability, fig6_utilization,
               fig10_11_fps, kernel_bench, noise_ablation, overload_bench,
               sdc_bench, serve_bench, table2_vdpe_size, table3_dkv_census,
               table4_comb_switch, table8_area_proportionate)

BENCHES = {
    "table2_vdpe_size": table2_vdpe_size.run,
    "fig4_5_scalability": fig4_5_scalability.run,
    "table3_dkv_census": table3_dkv_census.run,
    "table4_comb_switch": table4_comb_switch.run,
    "fig6_utilization": fig6_utilization.run,
    "table8_area_proportionate": table8_area_proportionate.run,
    "fig10_11_fps": fig10_11_fps.run,
    "kernel_bench": kernel_bench.run,
    "noise_ablation": noise_ablation.run,
    "serve_bench": serve_bench.run,     # smoke settings by default
    "chaos_bench": chaos_bench.run,     # fault-injection scenarios
    "sdc_bench": sdc_bench.run,         # silent-data-corruption defense
    "overload_bench": overload_bench.run,  # brownout ladder under overload
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip", action="append", default=[],
                    help="bench name to leave out (repeatable); e.g. the "
                         "nightly runs serve_bench separately in non-smoke "
                         "mode")
    args = ap.parse_args()
    unknown = [n for n in [args.only, *args.skip]
               if n is not None and n not in BENCHES]
    if unknown:
        ap.error(f"unknown bench name(s) {unknown}; "
                 f"choose from {sorted(BENCHES)}")
    failures = 0
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        if name in args.skip:
            continue
        t0 = time.monotonic()
        print(f"### {name}")
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        print(f"{name},wall_s,{time.monotonic() - t0:.2f}")
        print()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
