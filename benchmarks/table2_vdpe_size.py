"""Paper Table II: VDPE size N at 4-bit precision across bit rates."""
from repro.core import scalability as sc


def run() -> None:
    got = sc.table2()
    for arch, rows in got.items():
        for br, n in rows.items():
            ref = sc.PAPER_TABLE_II[arch][br]
            print(f"table2,{arch}@{br:g}Gbps,N={n},paper={ref},"
                  f"{'MATCH' if n == ref else 'MISMATCH'}")
